//! Table definitions and execution.

use crate::baselines::{esig_like, iisignature_like};
use crate::logsignature::{
    logsignature_from_sig, logsignature_vjp_with, LogSigBasis, LogSigPlan,
};
use crate::path::Path;
use crate::runtime::{ArtifactKind, EngineHandle, Registry};
use crate::signature::backward::signature_batch_vjp;
use crate::signature::{
    signature, signature_batch, signature_vjp, signature_vjp_with, signature_with, SigConfig,
};
use crate::substrate::benchlib::{bench, black_box, BenchConfig, Table};
use crate::substrate::pool::default_threads;
use crate::substrate::rng::Rng;
use crate::ta::opcount;
use crate::ta::SigSpec;

/// Benchmark scale: the paper's exact sizes, or scaled-down sweeps for
/// quick runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// Batch 32/1, stream 128, channels 2–7, depths 2–9, repeats up to 50
    /// (§6: "repeated 50 times and the fastest time taken").
    Paper,
    /// Batch 8/1, stream 64, channels 2–5, depths 2–6, few repeats.
    Small,
    /// Minimal smoke scale for `cargo bench` CI runs.
    Ci,
}

impl Scale {
    pub fn parse(s: &str) -> anyhow::Result<Scale> {
        Ok(match s {
            "paper" => Scale::Paper,
            "small" => Scale::Small,
            "ci" => Scale::Ci,
            other => anyhow::bail!("unknown scale {other:?} (paper|small|ci)"),
        })
    }

    fn batch(&self) -> usize {
        match self {
            Scale::Paper => 32,
            Scale::Small => 8,
            Scale::Ci => 4,
        }
    }

    fn stream(&self) -> usize {
        match self {
            Scale::Paper => 128,
            Scale::Small => 64,
            Scale::Ci => 32,
        }
    }

    fn channel_axis(&self) -> Vec<usize> {
        match self {
            Scale::Paper => (2..=7).collect(),
            Scale::Small => (2..=5).collect(),
            Scale::Ci => (2..=3).collect(),
        }
    }

    fn depth_axis(&self) -> Vec<usize> {
        match self {
            Scale::Paper => (2..=9).collect(),
            Scale::Small => (2..=6).collect(),
            Scale::Ci => (2..=4).collect(),
        }
    }

    /// Fixed depth when sweeping channels / fixed channels when sweeping
    /// depth (paper: depth 7 / channels 4).
    fn fixed_depth(&self) -> usize {
        match self {
            Scale::Paper => 7,
            Scale::Small => 5,
            Scale::Ci => 3,
        }
    }

    fn fixed_channels(&self) -> usize {
        4
    }

    fn bench_config(&self) -> BenchConfig {
        match self {
            Scale::Paper => BenchConfig {
                warmup: 1,
                repeats: 50,
                budget: std::time::Duration::from_secs(15),
                min_repeats: 2,
            },
            Scale::Small => BenchConfig {
                warmup: 1,
                repeats: 10,
                budget: std::time::Duration::from_secs(4),
                min_repeats: 2,
            },
            Scale::Ci => BenchConfig::quick(),
        }
    }
}

/// Execution context: scale, threads, optional XLA engine.
pub struct BenchCtx {
    pub scale: Scale,
    pub threads: usize,
    pub xla: Option<(EngineHandle, Registry)>,
}

impl BenchCtx {
    pub fn new(scale: Scale, artifact_dir: Option<std::path::PathBuf>) -> BenchCtx {
        let xla = artifact_dir.and_then(|dir| {
            if dir.join("MANIFEST.json").exists() {
                EngineHandle::spawn(dir).ok()
            } else {
                None
            }
        });
        BenchCtx { scale, threads: default_threads(), xla }
    }
}

/// All runnable table ids.
pub fn table_ids() -> Vec<&'static str> {
    vec![
        "1", "2", "3", "4", "5", "6", "7", "8", "9", "10", "11", "12", "13", "14", "15", "16",
        "opcount", "path", "memory", "backward", "batch",
    ]
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Op {
    SigFwd,
    SigBwd,
    LogSigFwd,
    LogSigBwd,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Axis {
    Channels,
    Depth,
}

struct TableSpec {
    title: &'static str,
    op: Op,
    axis: Axis,
    batch_one: bool,
}

fn spec_for(id: &str) -> Option<TableSpec> {
    let t = |title, op, axis, batch_one| Some(TableSpec { title, op, axis, batch_one });
    match id {
        "1" => t("Table 1 / Fig 1a: signature forward, varying channels", Op::SigFwd, Axis::Channels, false),
        "2" => t("Table 2 / Fig 2a: signature backward, varying channels", Op::SigBwd, Axis::Channels, false),
        "3" => t("Table 3 / Fig 1b: signature forward, varying depths", Op::SigFwd, Axis::Depth, false),
        "4" => t("Table 4 / Fig 2b: signature backward, varying depths", Op::SigBwd, Axis::Depth, false),
        "5" => t("Table 5 / Fig 4a: logsignature forward, varying channels", Op::LogSigFwd, Axis::Channels, false),
        "6" => t("Table 6 / Fig 4b: logsignature backward, varying channels", Op::LogSigBwd, Axis::Channels, false),
        "7" => t("Table 7 / Fig 4c: logsignature forward, varying depths", Op::LogSigFwd, Axis::Depth, false),
        "8" => t("Table 8 / Fig 4d: logsignature backward, varying depths", Op::LogSigBwd, Axis::Depth, false),
        "9" => t("Table 9 / Fig 5a: signature forward, varying channels, batch 1", Op::SigFwd, Axis::Channels, true),
        "10" => t("Table 10 / Fig 5b: signature backward, varying channels, batch 1", Op::SigBwd, Axis::Channels, true),
        "11" => t("Table 11 / Fig 5c: signature forward, varying depths, batch 1", Op::SigFwd, Axis::Depth, true),
        "12" => t("Table 12 / Fig 5d: signature backward, varying depths, batch 1", Op::SigBwd, Axis::Depth, true),
        "13" => t("Table 13 / Fig 6a: logsignature forward, varying channels, batch 1", Op::LogSigFwd, Axis::Channels, true),
        "14" => t("Table 14 / Fig 6b: logsignature backward, varying channels, batch 1", Op::LogSigBwd, Axis::Channels, true),
        "15" => t("Table 15 / Fig 6c: logsignature forward, varying depths, batch 1", Op::LogSigFwd, Axis::Depth, true),
        "16" => t("Table 16 / Fig 6d: logsignature backward, varying depths, batch 1", Op::LogSigBwd, Axis::Depth, true),
        _ => None,
    }
}

/// Run one table by id.
pub fn run_table(ctx: &BenchCtx, id: &str) -> anyhow::Result<Table> {
    match id {
        "opcount" => return Ok(opcount_table(ctx)),
        "path" => return Ok(path_table(ctx)),
        "memory" => return Ok(memory_table(ctx)),
        "backward" => return Ok(backward_table(ctx)),
        "batch" => return Ok(batch_table(ctx)),
        _ => {}
    }
    let spec = spec_for(id).ok_or_else(|| anyhow::anyhow!("unknown table {id:?}"))?;
    Ok(benchmark_table(ctx, id, &spec))
}

struct Point {
    d: usize,
    depth: usize,
}

fn axis_points(ctx: &BenchCtx, axis: Axis) -> (String, Vec<Point>, Vec<String>) {
    match axis {
        Axis::Channels => {
            let ds = ctx.scale.channel_axis();
            let cols = ds.iter().map(|d| d.to_string()).collect();
            let pts = ds.iter().map(|&d| Point { d, depth: ctx.scale.fixed_depth() }).collect();
            ("Channels".to_string(), pts, cols)
        }
        Axis::Depth => {
            let ns = ctx.scale.depth_axis();
            let cols = ns.iter().map(|n| n.to_string()).collect();
            let pts = ns.iter().map(|&n| Point { d: ctx.scale.fixed_channels(), depth: n }).collect();
            ("Depth".to_string(), pts, cols)
        }
    }
}

fn benchmark_table(ctx: &BenchCtx, id: &str, tspec: &TableSpec) -> Table {
    let batch = if tspec.batch_one { 1 } else { ctx.scale.batch() };
    let stream = ctx.scale.stream();
    let (axis_name, points, cols) = axis_points(ctx, tspec.axis);
    let cfg = ctx.scale.bench_config();

    let mut rows: Vec<(String, Vec<Option<f64>>)> = vec![
        ("esig_like".into(), vec![]),
        ("iisignature_like".into(), vec![]),
        ("signax CPU (no parallel)".into(), vec![]),
        ("signax CPU (parallel)".into(), vec![]),
        ("signax XLA".into(), vec![]),
    ];

    for p in &points {
        let sspec = SigSpec::new(p.d, p.depth).expect("valid spec");
        let mut rng = Rng::new(0xBEEF ^ (p.d as u64) << 8 ^ p.depth as u64);
        let paths = crate::data::random_batch(&mut rng, batch, stream, p.d, 0.2);
        let len = sspec.sig_len();
        let cot = rng.normal_vec(batch * len, 1.0);
        let plan = match tspec.op {
            Op::LogSigFwd | Op::LogSigBwd => {
                Some(LogSigPlan::new(&sspec, LogSigBasis::Words).expect("plan"))
            }
            _ => None,
        };
        // iisignature produces the Lyndon basis; its stand-in pays that
        // projection cost (cheap next to the sig itself at these sizes).
        let lyndon_plan = match tspec.op {
            Op::LogSigFwd | Op::LogSigBwd => {
                Some(LogSigPlan::new(&sspec, LogSigBasis::Lyndon).expect("plan"))
            }
            _ => None,
        };
        let per_path = stream * p.d;

        // --- esig_like ---
        let esig_cell = match tspec.op {
            Op::SigFwd if esig_like::supports(&sspec) => Some(
                bench(&cfg, || {
                    for b in 0..batch {
                        black_box(
                            esig_like::signature(&paths[b * per_path..(b + 1) * per_path], stream, &sspec)
                                .unwrap(),
                        );
                    }
                })
                .best_secs(),
            ),
            Op::LogSigFwd if esig_like::supports(&sspec) => {
                let lp = lyndon_plan.as_ref().unwrap();
                Some(
                    bench(&cfg, || {
                        for b in 0..batch {
                            let sig = esig_like::signature(
                                &paths[b * per_path..(b + 1) * per_path],
                                stream,
                                &sspec,
                            )
                            .unwrap();
                            black_box(logsignature_from_sig(&sig, &sspec, lp).unwrap());
                        }
                    })
                    .best_secs(),
                )
            }
            _ => None, // esig has no backward and no large ops
        };
        rows[0].1.push(esig_cell);

        // --- iisignature_like ---
        let iis_cell = match tspec.op {
            Op::SigFwd => Some(
                bench(&cfg, || {
                    for b in 0..batch {
                        black_box(iisignature_like::signature(
                            &paths[b * per_path..(b + 1) * per_path],
                            stream,
                            &sspec,
                        ));
                    }
                })
                .best_secs(),
            ),
            Op::SigBwd => Some(
                bench(&cfg, || {
                    for b in 0..batch {
                        black_box(iisignature_like::signature_vjp(
                            &paths[b * per_path..(b + 1) * per_path],
                            stream,
                            &sspec,
                            &cot[b * len..(b + 1) * len],
                        ));
                    }
                })
                .best_secs(),
            ),
            Op::LogSigFwd => {
                let lp = lyndon_plan.as_ref().unwrap();
                Some(
                    bench(&cfg, || {
                        for b in 0..batch {
                            let sig = iisignature_like::signature(
                                &paths[b * per_path..(b + 1) * per_path],
                                stream,
                                &sspec,
                            );
                            black_box(logsignature_from_sig(&sig, &sspec, lp).unwrap());
                        }
                    })
                    .best_secs(),
                )
            }
            Op::LogSigBwd => {
                let lp = lyndon_plan.as_ref().unwrap();
                let gcot: Vec<f32> = rng.normal_vec(lp.dim(), 1.0);
                Some(
                    bench(&cfg, || {
                        for b in 0..batch {
                            let pb = &paths[b * per_path..(b + 1) * per_path];
                            // iisignature-style: conventional sig fwd (tape),
                            // log + Lyndon projection, then tape backward.
                            let sig = iisignature_like::signature(pb, stream, &sspec);
                            let g_sig =
                                crate::logsignature::logsignature_from_sig_vjp(&sig, &sspec, lp, &gcot)
                                    .unwrap();
                            black_box(iisignature_like::signature_vjp(pb, stream, &sspec, &g_sig));
                        }
                    })
                    .best_secs(),
                )
            }
        };
        rows[1].1.push(iis_cell);

        // --- signax CPU (no parallel) ---
        let serial_cell = match tspec.op {
            Op::SigFwd => Some(
                bench(&cfg, || {
                    for b in 0..batch {
                        black_box(signature(&paths[b * per_path..(b + 1) * per_path], stream, &sspec));
                    }
                })
                .best_secs(),
            ),
            Op::SigBwd => Some(
                bench(&cfg, || {
                    for b in 0..batch {
                        black_box(signature_vjp(
                            &paths[b * per_path..(b + 1) * per_path],
                            stream,
                            &sspec,
                            &cot[b * len..(b + 1) * len],
                        ));
                    }
                })
                .best_secs(),
            ),
            Op::LogSigFwd => {
                let wp = plan.as_ref().unwrap();
                Some(
                    bench(&cfg, || {
                        for b in 0..batch {
                            let sig = signature(&paths[b * per_path..(b + 1) * per_path], stream, &sspec);
                            black_box(logsignature_from_sig(&sig, &sspec, wp).unwrap());
                        }
                    })
                    .best_secs(),
                )
            }
            Op::LogSigBwd => {
                let wp = plan.as_ref().unwrap();
                let gcot: Vec<f32> = rng.normal_vec(wp.dim(), 1.0);
                Some(
                    bench(&cfg, || {
                        for b in 0..batch {
                            black_box(
                                logsignature_vjp_with(
                                    &paths[b * per_path..(b + 1) * per_path],
                                    stream,
                                    &sspec,
                                    wp,
                                    &SigConfig::serial(),
                                    &gcot,
                                )
                                .unwrap(),
                            );
                        }
                    })
                    .best_secs(),
                )
            }
        };
        rows[2].1.push(serial_cell);

        // --- signax CPU (parallel) ---
        // Batch >= 2: parallel over the batch. Batch 1: chunked stream
        // reduction for the forward, and the chunked Chen-identity
        // stream-parallel backward (signature::backward) for the VJPs —
        // the paper's App. C.3 left this cell blank; we fill it.
        let parallel_cell = match (tspec.op, batch) {
            (Op::SigFwd, 1) => {
                let scfg = SigConfig::parallel(ctx.threads);
                Some(
                    bench(&cfg, || {
                        black_box(signature_with(&paths, stream, &sspec, &scfg).unwrap());
                    })
                    .best_secs(),
                )
            }
            (Op::SigFwd, _) => Some(
                bench(&cfg, || {
                    black_box(signature_batch(&paths, batch, stream, &sspec, ctx.threads).unwrap());
                })
                .best_secs(),
            ),
            (Op::SigBwd, 1) => {
                let scfg = SigConfig::parallel(ctx.threads);
                Some(
                    bench(&cfg, || {
                        black_box(
                            signature_vjp_with(&paths, stream, &sspec, &scfg, &cot)
                                .unwrap()
                                .grad_path,
                        );
                    })
                    .best_secs(),
                )
            }
            (Op::SigBwd, _) => Some(
                bench(&cfg, || {
                    black_box(
                        signature_batch_vjp(&paths, batch, stream, &sspec, &cot, ctx.threads).unwrap(),
                    );
                })
                .best_secs(),
            ),
            (Op::LogSigFwd, 1) => {
                let wp = plan.as_ref().unwrap();
                let scfg = SigConfig::parallel(ctx.threads);
                Some(
                    bench(&cfg, || {
                        let sig = signature_with(&paths, stream, &sspec, &scfg).unwrap();
                        black_box(logsignature_from_sig(&sig, &sspec, wp).unwrap());
                    })
                    .best_secs(),
                )
            }
            (Op::LogSigFwd, _) => {
                let wp = plan.as_ref().unwrap();
                Some(
                    bench(&cfg, || {
                        let out = crate::substrate::pool::parallel_map_indexed(batch, ctx.threads, |b| {
                            let sig = signature(&paths[b * per_path..(b + 1) * per_path], stream, &sspec);
                            logsignature_from_sig(&sig, &sspec, wp).unwrap()
                        });
                        black_box(out);
                    })
                    .best_secs(),
                )
            }
            (Op::LogSigBwd, 1) => {
                let wp = plan.as_ref().unwrap();
                let gcot: Vec<f32> = rng.normal_vec(wp.dim(), 1.0);
                let scfg = SigConfig::parallel(ctx.threads);
                Some(
                    bench(&cfg, || {
                        black_box(
                            logsignature_vjp_with(&paths, stream, &sspec, wp, &scfg, &gcot)
                                .unwrap(),
                        );
                    })
                    .best_secs(),
                )
            }
            (Op::LogSigBwd, _) => {
                let wp = plan.as_ref().unwrap();
                let gcot: Vec<f32> = rng.normal_vec(wp.dim(), 1.0);
                Some(
                    bench(&cfg, || {
                        let out = crate::substrate::pool::parallel_map_indexed(batch, ctx.threads, |b| {
                            logsignature_vjp_with(
                                &paths[b * per_path..(b + 1) * per_path],
                                stream,
                                &sspec,
                                wp,
                                &SigConfig::serial(),
                                &gcot,
                            )
                            .unwrap()
                        });
                        black_box(out);
                    })
                    .best_secs(),
                )
            }
        };
        rows[3].1.push(parallel_cell);

        // --- signax XLA (accelerator path) ---
        let xla_cell = ctx.xla.as_ref().and_then(|(engine, registry)| {
            let kind = match tspec.op {
                Op::SigFwd => ArtifactKind::Sig,
                Op::SigBwd => ArtifactKind::SigGrad,
                Op::LogSigFwd => ArtifactKind::LogSig,
                Op::LogSigBwd => return None, // no logsig-grad artifact kind
            };
            let entry = registry.find(kind, batch, stream, p.d, p.depth)?.clone();
            engine.warm(&entry).ok()?;
            let secs = match tspec.op {
                Op::SigFwd | Op::LogSigFwd => bench(&cfg, || {
                    black_box(engine.forward(&entry, paths.clone()).unwrap());
                })
                .best_secs(),
                Op::SigBwd => bench(&cfg, || {
                    black_box(engine.grad(&entry, paths.clone(), cot.clone()).unwrap());
                })
                .best_secs(),
                Op::LogSigBwd => unreachable!(),
            };
            Some(secs)
        });
        rows[4].1.push(xla_cell);
    }

    let mut table = Table::new(
        &format!("{} [batch={} stream={} scale={:?}]", tspec.title, batch, stream, ctx.scale),
        &axis_name,
        cols,
    );
    let _ = id;
    for (label, cells) in rows {
        table.push_row(&label, cells);
    }
    table.push_ratio_rows(
        "iisignature_like",
        &["signax CPU (no parallel)", "signax CPU (parallel)", "signax XLA"],
    );
    table
}

/// App. A.1.3: multiplication counts F(d, N) vs C(d, N) and the ratio.
fn opcount_table(ctx: &BenchCtx) -> Table {
    let depths = ctx.scale.depth_axis();
    let cols = depths.iter().map(|n| n.to_string()).collect();
    let mut table = Table::new(
        "Op-count (App. A.1.3): scalar multiplications per fused step, channels = 4",
        "Depth",
        cols,
    );
    let d = 4u64;
    table.push_row(
        "C(d,N) conventional",
        depths.iter().map(|&n| Some(opcount::conventional_muls(d, n as u64) as f64)).collect(),
    );
    table.push_row(
        "F(d,N) fused",
        depths.iter().map(|&n| Some(opcount::fused_muls(d, n as u64) as f64)).collect(),
    );
    table.push_row(
        "C/F ratio",
        depths
            .iter()
            .map(|&n| {
                let f = opcount::fused_muls(d, n as u64) as f64;
                if f == 0.0 {
                    None
                } else {
                    Some(opcount::conventional_muls(d, n as u64) as f64 / f)
                }
            })
            .collect(),
    );
    table
}

/// §4.2: O(1) interval queries vs direct recomputation, sweeping L.
fn path_table(ctx: &BenchCtx) -> Table {
    let lengths: Vec<usize> = match ctx.scale {
        Scale::Paper => vec![128, 512, 2048, 8192],
        Scale::Small => vec![128, 512, 2048],
        Scale::Ci => vec![64, 128],
    };
    let cfg = ctx.scale.bench_config();
    let spec = SigSpec::new(4, 4).expect("spec");
    let cols = lengths.iter().map(|l| l.to_string()).collect();
    let mut table = Table::new(
        "Path class (§4.2): arbitrary-interval queries, channels=4 depth=4 [times per 100 queries]",
        "Stream length",
        cols,
    );
    let mut precompute = vec![];
    let mut fast = vec![];
    let mut slow = vec![];
    for &l in &lengths {
        let mut rng = Rng::new(l as u64);
        let pts = crate::data::random_path(&mut rng, l, 4, 0.1);
        precompute.push(Some(
            bench(&cfg, || {
                black_box(Path::new(&spec, &pts, l).unwrap());
            })
            .best_secs(),
        ));
        let path = Path::new(&spec, &pts, l).unwrap();
        // 100 random intervals, fixed per L.
        let intervals: Vec<(usize, usize)> = (0..100)
            .map(|_| {
                let i = rng.below(l - 1);
                let j = rng.in_range(i + 1, l - 1);
                (i, j)
            })
            .collect();
        fast.push(Some(
            bench(&cfg, || {
                for &(i, j) in &intervals {
                    black_box(path.query(i, j).unwrap());
                }
            })
            .best_secs(),
        ));
        slow.push(Some(
            bench(&cfg, || {
                for &(i, j) in &intervals {
                    black_box(path.query_recompute(i, j).unwrap());
                }
            })
            .best_secs(),
        ));
    }
    table.push_row("precompute (O(L), once)", precompute);
    table.push_row("100 queries, O(1) precomputed", fast);
    table.push_row("100 queries, recompute", slow);
    table.push_ratio_rows("100 queries, recompute", &["100 queries, O(1) precomputed"]);
    table
}

/// App. D.2: backward-pass retained memory — reversibility vs tape.
fn memory_table(ctx: &BenchCtx) -> Table {
    let stream = ctx.scale.stream();
    let depths = ctx.scale.depth_axis();
    let cols = depths.iter().map(|n| n.to_string()).collect();
    let mut table = Table::new(
        &format!(
            "Backward-pass retained memory (App. D.2), channels=4 stream={stream} [bytes]"
        ),
        "Depth",
        cols,
    );
    let mut tape = vec![];
    let mut rev = vec![];
    for &n in &depths {
        let spec = SigSpec::new(4, n).expect("spec");
        tape.push(Some(iisignature_like::tape_bytes(stream, &spec) as f64));
        // Reversibility retains: current signature + cotangent + one
        // scratch signature + Horner buffers (Workspace) — O(1) in L.
        let ws = 2 * (spec.level_len(n.max(2)) / spec.d().max(1)) + 3 * spec.sig_len();
        rev.push(Some(((3 * spec.sig_len() + ws) * 4) as f64));
    }
    table.push_row("iisignature_like tape (O(L))", tape);
    table.push_row("signax reversibility (O(1))", rev);
    table.push_ratio_rows("iisignature_like tape (O(L))", &["signax reversibility (O(1))"]);
    table
}

/// Tentpole benchmark: serial vs chunked-Chen stream-parallel backward
/// over long single streams (batch 1, channels=4 depth=4), the regime the
/// paper's App. C.3 declared serial. Also records the machine-readable
/// perf trajectory to `BENCH_backward.json` in the working directory.
fn backward_table(ctx: &BenchCtx) -> Table {
    let lengths: Vec<usize> = match ctx.scale {
        Scale::Paper => vec![512, 2048, 8192],
        Scale::Small => vec![256, 1024, 4096],
        Scale::Ci => vec![64, 256],
    };
    let cfg = ctx.scale.bench_config();
    let spec = SigSpec::new(4, 4).expect("spec");
    let threads = ctx.threads;
    let cols = lengths.iter().map(|l| l.to_string()).collect();
    let mut table = Table::new(
        &format!(
            "Stream-parallel backward (chunked Chen identity), channels=4 depth=4 threads={threads}"
        ),
        "Stream length",
        cols,
    );
    let mut serial_row = vec![];
    let mut parallel_row = vec![];
    let mut records = vec![];
    for &l in &lengths {
        let mut rng = Rng::new(0xBAC ^ l as u64);
        let path = crate::data::random_path(&mut rng, l, 4, 0.1);
        let cot = rng.normal_vec(spec.sig_len(), 1.0);
        let serial = bench(&cfg, || {
            black_box(signature_vjp(&path, l, &spec, &cot));
        })
        .best_secs();
        let pcfg = SigConfig::parallel(threads);
        let parallel = bench(&cfg, || {
            black_box(signature_vjp_with(&path, l, &spec, &pcfg, &cot).unwrap().grad_path);
        })
        .best_secs();
        serial_row.push(Some(serial));
        parallel_row.push(Some(parallel));
        records.push((l, threads, serial, parallel));
    }
    let parallel_label = format!("chunked Chen ({threads} threads)");
    table.push_row("serial reverse sweep", serial_row);
    table.push_row(&parallel_label, parallel_row);
    table.push_ratio_rows("serial reverse sweep", &[parallel_label.as_str()]);
    // Machine-readable record for the perf trajectory; best-effort (a
    // read-only working directory must not fail the table) and skipped
    // under `cargo test` so the smoke test leaves no droppings.
    if !cfg!(test) {
        // hw_threads records machine capability (same meaning as the
        // standalone bench); per-point `threads` records what was used.
        let json = backward_json(default_threads(), &records);
        if let Err(e) = std::fs::write("BENCH_backward.json", json) {
            eprintln!("note: could not write BENCH_backward.json: {e}");
        }
    }
    table
}

/// Batch-lane engine (serving regime): lane-fused forward vs per-path
/// dispatch over the lane count, at small `d` and a short stream — the
/// many-short-streams workload where one-thread-per-path leaves the SIMD
/// lanes idle. Single-threaded on both sides so the ratio isolates lane
/// utilisation rather than thread scaling. The standalone
/// `benches/batch_lanes.rs` sweep (forward *and* backward) writes the
/// machine-readable `BENCH_batch.json`.
fn batch_table(ctx: &BenchCtx) -> Table {
    let lanes_axis: Vec<usize> = vec![1, 4, 8, 16];
    let ds: Vec<usize> = match ctx.scale {
        Scale::Paper => vec![2, 4, 8],
        Scale::Small => vec![2, 4],
        Scale::Ci => vec![2],
    };
    let depth = 4;
    let stream = 32;
    let cfg = ctx.scale.bench_config();
    let cols = lanes_axis.iter().map(|l| l.to_string()).collect();
    let mut table = Table::new(
        &format!(
            "Batch-lane engine (serving regime): forward, depth={depth} stream={stream}, 1 thread"
        ),
        "Lanes",
        cols,
    );
    for &d in &ds {
        let sspec = SigSpec::new(d, depth).expect("valid spec");
        let mut per_path_row = vec![];
        let mut lane_row = vec![];
        for &lanes in &lanes_axis {
            let mut rng = Rng::new(0x1A7E ^ ((d as u64) << 8) ^ lanes as u64);
            let paths = crate::data::random_batch(&mut rng, lanes, stream, d, 0.2);
            let plen = stream * d;
            per_path_row.push(Some(
                bench(&cfg, || {
                    for b in 0..lanes {
                        black_box(signature(&paths[b * plen..(b + 1) * plen], stream, &sspec));
                    }
                })
                .best_secs(),
            ));
            lane_row.push(Some(
                bench(&cfg, || {
                    black_box(signature_batch(&paths, lanes, stream, &sspec, 1).unwrap());
                })
                .best_secs(),
            ));
        }
        let base = format!("d={d} per-path dispatch");
        let lane_label = format!("d={d} lane-fused");
        table.push_row(&base, per_path_row);
        table.push_row(&lane_label, lane_row);
        table.push_ratio_rows(&base, &[lane_label.as_str()]);
    }
    table
}

/// Render batch-lane bench records as `BENCH_batch.json`: `points[]` of
/// `(op, prec, d, depth, lanes, stream, per_path_s, lane_s, speedup)`
/// under top-level `hw_threads`. Written by `benches/batch_lanes.rs`;
/// the acceptance point is >= 2x forward speedup at `lanes = 16, d = 2`
/// in f32. Depth moved per-point (the beyond-the-mono-window sweep runs
/// one level shallower) and each point carries its precision label;
/// `op = "vjp_step"` points record the mono-vs-dyn kernel crossover
/// (`per_path_s` = const-`D` dispatch, `lane_s` = runtime-`d` body).
#[allow(clippy::type_complexity)]
pub fn batch_json(
    hw_threads: usize,
    records: &[(&str, &str, usize, usize, usize, usize, f64, f64)],
) -> String {
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"batch_lanes\",\n");
    s.push_str(&format!("  \"hw_threads\": {hw_threads},\n"));
    s.push_str("  \"points\": [\n");
    for (i, &(op, prec, d, depth, lanes, stream, per_path, lane)) in records.iter().enumerate() {
        let comma = if i + 1 == records.len() { "" } else { "," };
        s.push_str(&format!(
            "    {{\"op\": \"{op}\", \"prec\": \"{prec}\", \"d\": {d}, \"depth\": {depth}, \"lanes\": {lanes}, \"stream\": {stream}, \"per_path_s\": {per_path:.9}, \"lane_s\": {lane:.9}, \"speedup\": {:.3}}}{comma}\n",
            per_path / lane
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Mono-vs-dyn retirement evidence: parse a `BENCH_batch.json` document
/// and return its `op = "vjp_step"` crossover records as
/// `(d, mono_s, dyn_s)`, sorted by `d`, with the structure the
/// retirement decision rests on asserted — at least one record inside
/// the mono window (`d <=` [`crate::exec::LANE_VJP_MAX_D`]) and one
/// beyond it, every timing positive. The mono bodies can be retired the
/// day the in-window records show `mono_s / dyn_s >= 1` across the
/// board; tooling (and the `benches/batch_lanes.rs --check` smoke)
/// reads the evidence through this helper instead of re-parsing the
/// JSON ad hoc, so a schema drift fails loudly at the source.
pub fn mono_dyn_crossover(json: &str) -> anyhow::Result<Vec<(usize, f64, f64)>> {
    let doc = crate::substrate::json::Json::parse(json)?;
    let pts = doc
        .get("points")
        .and_then(|p| p.as_arr())
        .ok_or_else(|| anyhow::anyhow!("BENCH_batch.json has no points[]"))?;
    let mut out: Vec<(usize, f64, f64)> = vec![];
    for p in pts {
        if p.get("op").and_then(|v| v.as_str()) != Some("vjp_step") {
            continue;
        }
        let d = p
            .get("d")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow::anyhow!("vjp_step point without a d"))?;
        let mono = p
            .get("per_path_s")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("vjp_step d={d} has no per_path_s (mono)"))?;
        let dynt = p
            .get("lane_s")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("vjp_step d={d} has no lane_s (dyn)"))?;
        anyhow::ensure!(
            mono > 0.0 && dynt > 0.0,
            "vjp_step d={d} has a non-positive timing (mono {mono}, dyn {dynt})"
        );
        out.push((d, mono, dynt));
    }
    out.sort_unstable_by_key(|&(d, ..)| d);
    anyhow::ensure!(
        out.iter().any(|&(d, ..)| d <= crate::exec::LANE_VJP_MAX_D),
        "no crossover record inside the mono window (d <= {})",
        crate::exec::LANE_VJP_MAX_D
    );
    anyhow::ensure!(
        out.iter().any(|&(d, ..)| d > crate::exec::LANE_VJP_MAX_D),
        "no crossover record beyond the mono window (d > {})",
        crate::exec::LANE_VJP_MAX_D
    );
    Ok(out)
}

/// Render backward bench records as `BENCH_backward.json` (no serde
/// offline; the format is flat enough to emit by hand). Shared by the
/// `backward` table and `benches/backward_scaling.rs` so both producers
/// write one schema: `points[]` of `(stream, threads, serial_s,
/// parallel_s, speedup)` under top-level `hw_threads`.
pub fn backward_json(hw_threads: usize, records: &[(usize, usize, f64, f64)]) -> String {
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"backward\",\n");
    s.push_str("  \"channels\": 4,\n  \"depth\": 4,\n");
    s.push_str(&format!("  \"hw_threads\": {hw_threads},\n"));
    s.push_str("  \"points\": [\n");
    for (i, &(stream, threads, serial, parallel)) in records.iter().enumerate() {
        let comma = if i + 1 == records.len() { "" } else { "," };
        s.push_str(&format!(
            "    {{\"stream\": {stream}, \"threads\": {threads}, \"serial_s\": {serial:.9}, \"parallel_s\": {parallel:.9}, \"speedup\": {:.3}}}{comma}\n",
            serial / parallel
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Render batched-logsignature bench records as `BENCH_logsig.json`:
/// `points[]` of `(op, basis, d, lanes, stream, per_path_s, lane_s,
/// speedup)` under top-level `hw_threads` / `depth`. Written by
/// `benches/logsig_batch.rs` — the logsig mirror of [`batch_json`], swept
/// over lane count x basis; every timed point is first gated on bitwise
/// equality between the lane-fused rows and per-path scalar dispatch.
#[allow(clippy::type_complexity)]
pub fn logsig_json(
    hw_threads: usize,
    depth: usize,
    records: &[(&str, &str, usize, usize, usize, f64, f64)],
) -> String {
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"logsig_batch\",\n");
    s.push_str(&format!("  \"depth\": {depth},\n"));
    s.push_str(&format!("  \"hw_threads\": {hw_threads},\n"));
    s.push_str("  \"points\": [\n");
    for (i, &(op, basis, d, lanes, stream, per_path, lane)) in records.iter().enumerate() {
        let comma = if i + 1 == records.len() { "" } else { "," };
        s.push_str(&format!(
            "    {{\"op\": \"{op}\", \"basis\": \"{basis}\", \"d\": {d}, \"lanes\": {lanes}, \
             \"stream\": {stream}, \"per_path_s\": {per_path:.9}, \"lane_s\": {lane:.9}, \
             \"speedup\": {:.3}}}{comma}\n",
            per_path / lane
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Render session-streaming bench records as `BENCH_sessions.json`:
/// `points[]` of `(threads, wall_s, feeds_per_s)` under top-level
/// `hw_threads`. Written by `benches/session_streaming.rs`; the feed
/// throughput for distinct sessions must scale with client threads
/// (a table-wide lock would flatline the curve).
pub fn sessions_json(hw_threads: usize, records: &[(usize, f64, f64)]) -> String {
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"sessions\",\n");
    s.push_str(&format!("  \"hw_threads\": {hw_threads},\n"));
    s.push_str("  \"points\": [\n");
    for (i, &(threads, wall, rate)) in records.iter().enumerate() {
        let comma = if i + 1 == records.len() { "" } else { "," };
        s.push_str(&format!(
            "    {{\"threads\": {threads}, \"wall_s\": {wall:.9}, \"feeds_per_s\": {rate:.3}}}{comma}\n"
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Render session-persistence bench records as `BENCH_persist.json`:
/// `points[]` of `(phase, sessions, wall_s, ops_per_s)` under top-level
/// `hw_threads`. Written by `benches/session_persistence.rs`, which
/// times spill/reload churn under budget pressure, reload-on-touch
/// latency, and warm-restart recovery vs session count — every phase
/// behind a bitwise spill -> touch -> reload gate.
pub fn persist_json(hw_threads: usize, records: &[(&str, usize, f64, f64)]) -> String {
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"session_persistence\",\n");
    s.push_str(&format!("  \"hw_threads\": {hw_threads},\n"));
    s.push_str("  \"points\": [\n");
    for (i, &(phase, sessions, wall, rate)) in records.iter().enumerate() {
        let comma = if i + 1 == records.len() { "" } else { "," };
        s.push_str(&format!(
            "    {{\"phase\": \"{phase}\", \"sessions\": {sessions}, \"wall_s\": {wall:.9}, \
             \"ops_per_s\": {rate:.3}}}{comma}\n"
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Render rolling-window soak records as `BENCH_soak.json`. Written by
/// `benches/session_soak.rs` behind bitwise gates:
///
/// - `phases[]` of `(phase, events, wall_s, ops_per_s, p99_us)` — the
///   open flood, the Zipf feed/poll storm (eviction/reload churn), and
///   the drain, with the p99 taken from the per-kind latency histogram
///   ([`crate::coordinator::MetricsSnapshot::render_latency`]'s data).
/// - `speedup[]` of `(window_len, recompute_s, windowed_s)` — server-
///   maintained sliding windows vs recompute-per-slide over the same
///   stream; the acceptance point is >= 5x at `window_len >= 64` in the
///   full run.
/// - `memory[]` of `(history_points, windowed_bytes, unbounded_bytes)` —
///   a window session's storage after `history_points` have flowed
///   through vs an unbounded session holding them all: the windowed
///   column must stay flat (O(window)) while the unbounded one grows
///   (O(history)).
#[allow(clippy::type_complexity)]
pub fn soak_json(
    hw_threads: usize,
    sessions: usize,
    check: bool,
    phases: &[(&str, usize, f64, f64, f64)],
    speedup: &[(usize, f64, f64)],
    memory: &[(usize, usize, usize)],
) -> String {
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"session_soak\",\n");
    s.push_str(&format!("  \"hw_threads\": {hw_threads},\n"));
    s.push_str(&format!("  \"sessions\": {sessions},\n"));
    s.push_str(&format!("  \"check\": {check},\n"));
    s.push_str("  \"phases\": [\n");
    for (i, &(phase, events, wall, rate, p99)) in phases.iter().enumerate() {
        let comma = if i + 1 == phases.len() { "" } else { "," };
        s.push_str(&format!(
            "    {{\"phase\": \"{phase}\", \"events\": {events}, \"wall_s\": {wall:.9}, \
             \"ops_per_s\": {rate:.3}, \"p99_us\": {p99:.3}}}{comma}\n"
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"speedup\": [\n");
    for (i, &(len, recompute, windowed)) in speedup.iter().enumerate() {
        let comma = if i + 1 == speedup.len() { "" } else { "," };
        s.push_str(&format!(
            "    {{\"window_len\": {len}, \"recompute_s\": {recompute:.9}, \
             \"windowed_s\": {windowed:.9}, \"speedup\": {:.3}}}{comma}\n",
            recompute / windowed
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"memory\": [\n");
    for (i, &(history, windowed, unbounded)) in memory.iter().enumerate() {
        let comma = if i + 1 == memory.len() { "" } else { "," };
        s.push_str(&format!(
            "    {{\"history_points\": {history}, \"windowed_bytes\": {windowed}, \
             \"unbounded_bytes\": {unbounded}}}{comma}\n"
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Render window-lane bench records as `BENCH_window.json`: `points[]`
/// of `(prec, basis, d, depth, window_len, stride, lanes, scalar_s,
/// batched_s, speedup)` under top-level `hw_threads`. Written by
/// `benches/window_lanes.rs`, which times lane-fused window-slide
/// advancement ([`crate::path::RollingWindow::advance_batch`]) against
/// the per-session scalar loop over the same feeds; every timed point is
/// first gated on bitwise equality of the emitted slide rows. The
/// acceptance point is >= 1.5x at `lanes = 16, d = 2` in f32 in the full
/// run.
#[allow(clippy::type_complexity)]
pub fn window_json(
    hw_threads: usize,
    records: &[(&str, &str, usize, usize, usize, usize, usize, f64, f64)],
) -> String {
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"window_lanes\",\n");
    s.push_str(&format!("  \"hw_threads\": {hw_threads},\n"));
    s.push_str("  \"points\": [\n");
    for (i, &(prec, basis, d, depth, len, stride, lanes, scalar, batched)) in
        records.iter().enumerate()
    {
        let comma = if i + 1 == records.len() { "" } else { "," };
        s.push_str(&format!(
            "    {{\"prec\": \"{prec}\", \"basis\": \"{basis}\", \"d\": {d}, \
             \"depth\": {depth}, \"window_len\": {len}, \"stride\": {stride}, \
             \"lanes\": {lanes}, \"scalar_s\": {scalar:.9}, \"batched_s\": {batched:.9}, \
             \"speedup\": {:.3}}}{comma}\n",
            scalar / batched
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Render adaptive-dispatch bench records as `BENCH_dispatch.json`:
/// `points[]` of `(mode, phase, requests, wall_s, mean_latency_us,
/// batches, dispatch_scalar, dispatch_lane_fused, feed_lane_batches)`
/// under top-level `hw_threads`. Written by
/// `benches/adaptive_dispatch.rs`, which runs the same mixed-shape
/// workload under static and adaptive dispatch.
#[allow(clippy::type_complexity)]
pub fn dispatch_json(
    hw_threads: usize,
    records: &[(&str, &str, usize, f64, f64, u64, u64, u64, u64)],
) -> String {
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"adaptive_dispatch\",\n");
    s.push_str(&format!("  \"hw_threads\": {hw_threads},\n"));
    s.push_str("  \"points\": [\n");
    for (i, &(mode, phase, requests, wall, lat_us, batches, scalar, lane, feed)) in
        records.iter().enumerate()
    {
        let comma = if i + 1 == records.len() { "" } else { "," };
        s.push_str(&format!(
            "    {{\"mode\": \"{mode}\", \"phase\": \"{phase}\", \"requests\": {requests}, \
             \"wall_s\": {wall:.9}, \"mean_latency_us\": {lat_us:.3}, \"batches\": {batches}, \
             \"dispatch_scalar\": {scalar}, \"dispatch_lane_fused\": {lane}, \
             \"feed_lane_batches\": {feed}}}{comma}\n"
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ci_scale_smoke_table1() {
        let ctx = BenchCtx { scale: Scale::Ci, threads: 2, xla: None };
        let t = run_table(&ctx, "1").unwrap();
        // 5 system rows + 3 ratio rows (XLA ratio row absent values but row
        // exists), all with one cell per axis point.
        assert_eq!(t.cols.len(), 2);
        assert!(t.rows.len() >= 7);
        // esig supported at these sizes; iisignature always has values.
        let iis = t.rows.iter().find(|r| r.label == "iisignature_like").unwrap();
        assert!(iis.cells.iter().all(|c| c.is_some()));
        // Fused should not lose to the conventional baseline.
        let fused = t.rows.iter().find(|r| r.label == "signax CPU (no parallel)").unwrap();
        for (f, i) in fused.cells.iter().zip(&iis.cells) {
            assert!(f.unwrap() <= i.unwrap() * 1.5, "fused slower than baseline");
        }
    }

    #[test]
    fn ci_scale_smoke_backward_and_logsig() {
        let ctx = BenchCtx { scale: Scale::Ci, threads: 2, xla: None };
        for id in ["2", "7", "14"] {
            let t = run_table(&ctx, id).unwrap();
            assert!(!t.rows.is_empty(), "table {id}");
            let esig = t.rows.iter().find(|r| r.label == "esig_like").unwrap();
            if id == "2" || id == "14" {
                // backward: esig column must be all dashes.
                assert!(esig.cells.iter().all(|c| c.is_none()));
            }
        }
    }

    #[test]
    fn special_tables() {
        let ctx = BenchCtx { scale: Scale::Ci, threads: 2, xla: None };
        let t = run_table(&ctx, "opcount").unwrap();
        let ratio = t.rows.iter().find(|r| r.label == "C/F ratio").unwrap();
        // Ratio grows with depth.
        let vals: Vec<f64> = ratio.cells.iter().map(|c| c.unwrap()).collect();
        assert!(vals.windows(2).all(|w| w[1] >= w[0]));

        let t = run_table(&ctx, "path").unwrap();
        let fast = t.rows.iter().find(|r| r.label == "100 queries, O(1) precomputed").unwrap();
        let slow = t.rows.iter().find(|r| r.label == "100 queries, recompute").unwrap();
        // The precomputed query path should win at the largest L.
        let last = fast.cells.last().unwrap().unwrap();
        let slow_last = slow.cells.last().unwrap().unwrap();
        assert!(last < slow_last, "O(1) query not faster: {last} vs {slow_last}");

        let t = run_table(&ctx, "memory").unwrap();
        assert_eq!(t.rows.len(), 3);
    }

    #[test]
    fn backward_table_smoke_and_json() {
        let ctx = BenchCtx { scale: Scale::Ci, threads: 2, xla: None };
        let t = run_table(&ctx, "backward").unwrap();
        let serial = t.rows.iter().find(|r| r.label == "serial reverse sweep").unwrap();
        assert!(serial.cells.iter().all(|c| c.is_some()));
        assert!(t.rows.iter().any(|r| r.label.starts_with("Ratio chunked Chen")));
        // JSON rendering is well-formed enough for the in-tree parser.
        let json = backward_json(8, &[(2048, 8, 1.0, 0.25), (8192, 8, 4.0, 1.0)]);
        let parsed = crate::substrate::json::Json::parse(&json).unwrap();
        assert_eq!(parsed.get("hw_threads").and_then(|v| v.as_f64()), Some(8.0));
        let pts = parsed.get("points").and_then(|p| p.as_arr()).unwrap();
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[0].get("stream").and_then(|v| v.as_f64()), Some(2048.0));
        assert_eq!(pts[0].get("threads").and_then(|v| v.as_f64()), Some(8.0));
        assert_eq!(pts[0].get("speedup").and_then(|v| v.as_f64()), Some(4.0));
    }

    #[test]
    fn batch_table_smoke_and_json() {
        let ctx = BenchCtx { scale: Scale::Ci, threads: 2, xla: None };
        let t = run_table(&ctx, "batch").unwrap();
        assert_eq!(t.cols, vec!["1", "4", "8", "16"]);
        let per_path = t.rows.iter().find(|r| r.label == "d=2 per-path dispatch").unwrap();
        let lane = t.rows.iter().find(|r| r.label == "d=2 lane-fused").unwrap();
        assert!(per_path.cells.iter().all(|c| c.is_some()));
        assert!(lane.cells.iter().all(|c| c.is_some()));
        assert!(t.rows.iter().any(|r| r.label == "Ratio d=2 lane-fused"));
        // JSON rendering is well-formed enough for the in-tree parser.
        let json = batch_json(
            8,
            &[
                ("forward", "f32", 2, 4, 16, 32, 1.0, 0.4),
                ("backward", "f64", 12, 3, 16, 32, 3.0, 1.5),
            ],
        );
        let parsed = crate::substrate::json::Json::parse(&json).unwrap();
        let pts = parsed.get("points").and_then(|p| p.as_arr()).unwrap();
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[0].get("lanes").and_then(|v| v.as_f64()), Some(16.0));
        assert_eq!(pts[0].get("depth").and_then(|v| v.as_f64()), Some(4.0));
        assert_eq!(pts[0].get("speedup").and_then(|v| v.as_f64()), Some(2.5));
        assert_eq!(pts[1].get("d").and_then(|v| v.as_f64()), Some(12.0));
        assert_eq!(pts[1].get("speedup").and_then(|v| v.as_f64()), Some(2.0));
    }

    #[test]
    fn mono_dyn_crossover_reads_vjp_step_records() {
        // Round-trip through the writer: vjp_step points come back sorted
        // as (d, mono, dyn); non-crossover points are ignored.
        let json = batch_json(
            8,
            &[
                ("forward", "f32", 2, 4, 16, 32, 1.0, 0.4),
                ("vjp_step", "f32", 12, 3, 0, 0, 2.0e-6, 2.1e-6),
                ("vjp_step", "f32", 2, 4, 0, 0, 1.0e-6, 1.5e-6),
            ],
        );
        let xs = mono_dyn_crossover(&json).unwrap();
        assert_eq!(xs.len(), 2);
        assert_eq!(xs[0].0, 2);
        assert_eq!(xs[1].0, 12);
        assert!((xs[0].1 - 1.0e-6).abs() < 1e-12 && (xs[0].2 - 1.5e-6).abs() < 1e-12);
        // The evidence must cover both sides of the mono window.
        let only_in_window = batch_json(8, &[("vjp_step", "f32", 2, 4, 0, 0, 1.0, 1.0)]);
        assert!(mono_dyn_crossover(&only_in_window).is_err());
        let only_beyond = batch_json(8, &[("vjp_step", "f32", 20, 3, 0, 0, 1.0, 1.0)]);
        assert!(mono_dyn_crossover(&only_beyond).is_err());
        // A zeroed timing is a broken record, not evidence.
        let zeroed = batch_json(
            8,
            &[
                ("vjp_step", "f32", 2, 4, 0, 0, 0.0, 1.0),
                ("vjp_step", "f32", 12, 3, 0, 0, 1.0, 1.0),
            ],
        );
        assert!(mono_dyn_crossover(&zeroed).is_err());
    }

    #[test]
    fn logsig_json_well_formed() {
        let json = logsig_json(
            8,
            4,
            &[
                ("forward", "words", 2, 16, 32, 1.0, 0.5),
                ("backward", "lyndon", 2, 16, 32, 3.0, 2.0),
            ],
        );
        let parsed = crate::substrate::json::Json::parse(&json).unwrap();
        assert_eq!(parsed.get("depth").and_then(|v| v.as_f64()), Some(4.0));
        let pts = parsed.get("points").and_then(|p| p.as_arr()).unwrap();
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[0].get("lanes").and_then(|v| v.as_f64()), Some(16.0));
        assert_eq!(pts[0].get("speedup").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(pts[1].get("speedup").and_then(|v| v.as_f64()), Some(1.5));
    }

    #[test]
    fn window_json_well_formed() {
        let json = window_json(
            8,
            &[
                ("f32", "sig", 2, 3, 16, 4, 16, 1.0, 0.5),
                ("f64", "words", 3, 2, 64, 8, 4, 3.0, 2.0),
            ],
        );
        let parsed = crate::substrate::json::Json::parse(&json).unwrap();
        assert_eq!(parsed.get("hw_threads").and_then(|v| v.as_f64()), Some(8.0));
        let pts = parsed.get("points").and_then(|p| p.as_arr()).unwrap();
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[0].get("window_len").and_then(|v| v.as_f64()), Some(16.0));
        assert_eq!(pts[0].get("lanes").and_then(|v| v.as_f64()), Some(16.0));
        assert_eq!(pts[0].get("speedup").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(pts[1].get("basis").and_then(|v| v.as_str()), Some("words"));
        assert_eq!(pts[1].get("speedup").and_then(|v| v.as_f64()), Some(1.5));
    }

    #[test]
    fn dispatch_json_well_formed() {
        let json = dispatch_json(
            8,
            &[
                ("static", "mixed", 96, 1.5, 2000.0, 40, 0, 8, 0),
                ("adaptive", "mixed", 96, 0.9, 700.0, 12, 28, 8, 3),
            ],
        );
        let parsed = crate::substrate::json::Json::parse(&json).unwrap();
        assert_eq!(parsed.get("hw_threads").and_then(|v| v.as_f64()), Some(8.0));
        let pts = parsed.get("points").and_then(|p| p.as_arr()).unwrap();
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[1].get("batches").and_then(|v| v.as_f64()), Some(12.0));
        assert_eq!(pts[1].get("dispatch_scalar").and_then(|v| v.as_f64()), Some(28.0));
        assert_eq!(pts[1].get("feed_lane_batches").and_then(|v| v.as_f64()), Some(3.0));
    }

    #[test]
    fn sessions_json_well_formed() {
        let json = sessions_json(8, &[(1, 2.0, 100.0), (4, 0.6, 333.333)]);
        let parsed = crate::substrate::json::Json::parse(&json).unwrap();
        assert_eq!(parsed.get("hw_threads").and_then(|v| v.as_f64()), Some(8.0));
        let pts = parsed.get("points").and_then(|p| p.as_arr()).unwrap();
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[1].get("threads").and_then(|v| v.as_f64()), Some(4.0));
        assert!(pts[1].get("feeds_per_s").and_then(|v| v.as_f64()).unwrap() > 333.0);
    }

    #[test]
    fn persist_json_well_formed() {
        let json = persist_json(8, &[("churn", 16, 2.0, 100.0), ("recovery", 64, 0.5, 128.0)]);
        let parsed = crate::substrate::json::Json::parse(&json).unwrap();
        assert_eq!(parsed.get("hw_threads").and_then(|v| v.as_f64()), Some(8.0));
        let pts = parsed.get("points").and_then(|p| p.as_arr()).unwrap();
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[1].get("sessions").and_then(|v| v.as_f64()), Some(64.0));
        assert_eq!(pts[1].get("ops_per_s").and_then(|v| v.as_f64()), Some(128.0));
    }

    #[test]
    fn unknown_table_errors() {
        let ctx = BenchCtx { scale: Scale::Ci, threads: 1, xla: None };
        assert!(run_table(&ctx, "99").is_err());
    }
}
