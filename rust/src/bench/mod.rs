//! The benchmark harness regenerating every table and figure of the
//! paper's evaluation (§6, App. D). See DESIGN.md's experiment index.
//!
//! - Tables 1–4 / Figures 1–2: signature forward/backward, varying
//!   channels and depth, batch 32.
//! - Tables 5–8 / Figure 4: logsignature forward/backward.
//! - Tables 9–16 / Figures 5–6: all of the above at batch 1.
//! - `opcount`: the App. A.1.3 multiplication-count table (F vs C).
//! - `path`: the §4.2 O(1)-vs-recompute interval-query comparison.
//! - `memory`: the App. D.2 reversibility-vs-tape memory comparison.
//! - `backward`: serial vs chunked-Chen stream-parallel backward over
//!   long single streams; also writes `BENCH_backward.json`.
//! - `batch`: the batch-lane engine vs per-path dispatch in the serving
//!   regime (many short streams, small d); the standalone
//!   `benches/batch_lanes.rs` sweep writes `BENCH_batch.json`, and the
//!   logsig mirror `benches/logsig_batch.rs` (lane count x basis) writes
//!   `BENCH_logsig.json`.
//!
//! Rows mirror the paper's: `esig_like`, `iisignature_like` (baselines),
//! `signax CPU (no parallel)`, `signax CPU (parallel)` and `signax XLA`
//! (the accelerator path standing in for "Signatory GPU"), plus derived
//! "Ratio" rows against the strongest competitor. Cells where a system
//! cannot run print as dashes, exactly like esig's dashes in the paper.

pub mod tables;
pub mod workload;

pub use tables::{
    backward_json, batch_json, dispatch_json, logsig_json, mono_dyn_crossover, persist_json,
    run_table, sessions_json, soak_json, table_ids, window_json, BenchCtx, Scale,
};
pub use workload::{ChunkSizes, Workload, Zipf};
