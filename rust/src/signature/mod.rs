//! The signature transform (§2) with every variant the paper's `signature`
//! function provides (§5): stream mode, basepoint, initial condition,
//! inversion, batch, CPU parallelism — plus the handwritten backward pass
//! exploiting signature reversibility (§5.3, App. C), stream-parallel via
//! the chunked Chen identity (see [`backward`]), and the combine functions
//! exploiting the group-like structure (§5.5).
//!
//! Paths are flat `[f32]` buffers of shape `(stream, channels)` row-major;
//! batches are `(batch, stream, channels)`.

pub mod backward;
pub mod combine;
pub mod forward;

pub use backward::{
    signature_batch_vjp, signature_batch_vjp_planned, signature_stream_vjp, signature_vjp,
    signature_vjp_with, SigVjpResult, PARALLEL_BACKWARD_MIN_POINTS,
};
pub use combine::{multi_signature_combine, signature_combine, signature_combine_vjp};
pub use forward::{
    signature, signature_batch, signature_batch_planned, signature_batch_with, signature_stream,
    signature_stream_with, signature_with, two_point_signature, two_point_signature_into,
    LANE_BLOCK, MAX_LANE_WIDTH,
};

/// Options mirroring Signatory's `signature(...)` keyword arguments.
#[derive(Clone, Debug, Default)]
pub struct SigConfig {
    /// Prepend this point to the path before computing (Signatory's
    /// `basepoint`); `Some(vec![0.0; d])` reproduces `basepoint=True`.
    pub basepoint: Option<Vec<f32>>,
    /// Left-multiply the result by an existing signature (Signatory's
    /// `initial`), used for "keeping the signature up-to-date" (§5.5).
    pub initial: Option<Vec<f32>>,
    /// Compute the inverted signature `Sig(x)^{-1} = Sig(reverse(x))`
    /// (§5.4) instead.
    pub inverse: bool,
    /// Worker threads for the chunked ⊠-reduction over the stream (§5.1),
    /// used by both the forward pass and — via the chunked Chen-identity
    /// factorisation in [`backward`] — the backward pass. `1` = serial
    /// (the paper's "CPU no parallel" column).
    pub threads: usize,
}

impl SigConfig {
    pub fn serial() -> SigConfig {
        SigConfig { threads: 1, ..Default::default() }
    }

    pub fn parallel(threads: usize) -> SigConfig {
        SigConfig { threads, ..Default::default() }
    }

    /// Effective number of points the configured path has, including any
    /// basepoint.
    pub(crate) fn effective_len(&self, stream: usize) -> usize {
        stream + usize::from(self.basepoint.is_some())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_effective_len() {
        let mut c = SigConfig::serial();
        assert_eq!(c.effective_len(10), 10);
        c.basepoint = Some(vec![0.0, 0.0]);
        assert_eq!(c.effective_len(10), 11);
    }
}
