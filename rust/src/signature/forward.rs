//! Signature forward passes.
//!
//! The core loop is eq. (3) written as a reduction with respect to the
//! fused multiply-exponentiate (§4.1, §5.1): one `exp` for the first
//! increment, then one fused `⊠ exp` per remaining increment. Stream mode
//! (§5.5 "expanding intervals") emits every prefix signature for free.
//! Parallel mode splits the stream into chunks — ⊠ is associative — and
//! combines chunk signatures (§5.1).
//!
//! Batched paths take the **batch-lane engine** ([`crate::ta::batch`]):
//! lanes of up to [`MAX_LANE_WIDTH`] same-spec signatures advance together
//! through one lane-interleaved fused sweep per increment, so the
//! innermost loops vectorise across the batch regardless of `d` — the
//! serving-realistic regime (many short streams, small `d`) where
//! one-thread-per-path leaves the SIMD lanes idle. Lane blocks distribute
//! over threads; each lane reproduces per-path dispatch bit-for-bit.

use super::SigConfig;
use crate::exec::{ExecPlan, ExecPlanner, WorkShape};
use crate::parallel;
use crate::ta::batch::{fused_mexp_batch, unpack_lane, BatchWorkspace};
use crate::ta::exp::exp_in_place;
use crate::ta::fused::fused_mexp;
use crate::ta::inverse::inverse_into;
use crate::ta::mul::mul_assign;
use crate::ta::{Elem, SigSpec, Workspace};

/// Re-exported from the execution planner, which owns all strategy
/// constants (see [`crate::exec`]).
pub use crate::exec::{LANE_BLOCK, MAX_LANE_WIDTH};

/// Validate a `(stream, d)` path buffer against the spec.
fn check_path<E: Elem>(path: &[E], stream: usize, spec: &SigSpec) -> anyhow::Result<()> {
    anyhow::ensure!(
        path.len() == stream * spec.d(),
        "path buffer has {} values, expected stream({}) * channels({})",
        path.len(),
        stream,
        spec.d()
    );
    Ok(())
}

/// Validate a path buffer *and* the config's basepoint/initial shapes;
/// returns the effective point count (incl. basepoint). Shared by the
/// forward pass and the backward pass (whose parallel branch never calls
/// [`signature_with`], so it must not rely on the forward for checks).
pub(crate) fn check_path_with<E: Elem>(
    path: &[E],
    stream: usize,
    spec: &SigSpec,
    cfg: &SigConfig,
) -> anyhow::Result<usize> {
    check_path(path, stream, spec)?;
    let d = spec.d();
    let eff_len = cfg.effective_len(stream);
    anyhow::ensure!(
        eff_len >= 2,
        "a path must have at least two points (incl. basepoint) to define a signature, got {}",
        eff_len
    );
    if let Some(bp) = &cfg.basepoint {
        anyhow::ensure!(bp.len() == d, "basepoint has {} channels, expected {d}", bp.len());
    }
    if let Some(init) = &cfg.initial {
        anyhow::ensure!(
            init.len() == spec.sig_len(),
            "initial signature has {} values, expected {}",
            init.len(),
            spec.sig_len()
        );
    }
    Ok(eff_len)
}

/// Serial signature of the increments `z_i = p_{i+1} - p_i` of a point
/// view. `points(i)` must yield the i-th point as a slice of length d.
/// Writes into `out` (which must be zeroed = identity, or hold `initial`).
fn sig_of_points<'a, E: Elem>(
    spec: &SigSpec,
    n_points: usize,
    points: impl Fn(usize) -> &'a [E],
    out: &mut [E],
    ws: &mut Workspace<E>,
) {
    let d = spec.d();
    let mut z = vec![E::ZERO; d];
    for i in 1..n_points {
        let prev = points(i - 1);
        let cur = points(i);
        for c in 0..d {
            z[c] = cur[c] - prev[c];
        }
        fused_mexp(spec, out, &z, ws);
    }
}

/// `Sig^N(path)` — the plain signature transform of one path of
/// `stream >= 2` points in `R^d`. Panics on shape mismatch (use
/// [`signature_with`] for a fallible, configurable version).
pub fn signature<E: Elem>(path: &[E], stream: usize, spec: &SigSpec) -> Vec<E> {
    signature_with(path, stream, spec, &SigConfig::serial()).expect("valid path")
}

/// Signature with full options (basepoint / initial / inverse / threads).
/// Generic over the element precision: `&[f32]` paths run the f32 kernels
/// unchanged, `&[f64]` paths run the same sweep in double precision. The
/// config's basepoint / initial stay declared in f32 (the wire format) and
/// are lifted into `E` once up front — the identity for `E = f32`.
pub fn signature_with<E: Elem>(
    path: &[E],
    stream: usize,
    spec: &SigSpec,
    cfg: &SigConfig,
) -> anyhow::Result<Vec<E>> {
    let d = spec.d();
    let eff_len = check_path_with(path, stream, spec, cfg)?;

    let basepoint: Option<Vec<E>> =
        cfg.basepoint.as_ref().map(|bp| bp.iter().map(|&v| E::from_f32(v)).collect());
    // Materialise the effective point sequence accessor (with basepoint and
    // possible reversal for the inverted signature, §5.4).
    let point = |i: usize| -> &[E] {
        let i = if cfg.inverse { eff_len - 1 - i } else { i };
        match &basepoint {
            Some(bp) => {
                if i == 0 {
                    bp.as_slice()
                } else {
                    &path[(i - 1) * d..i * d]
                }
            }
            None => &path[i * d..(i + 1) * d],
        }
    };

    let mut out = match &cfg.initial {
        Some(init) => init.iter().map(|&v| E::from_f32(v)).collect(),
        None => spec.zeros_elem::<E>(),
    };
    // Strategy selection lives in the execution planner (crate::exec);
    // this function only executes whichever plan comes back.
    let plan = ExecPlanner::new(cfg.threads).plan_forward(&WorkShape {
        batch: 1,
        points: eff_len,
        d,
        depth: spec.depth(),
        dtype: E::PRECISION,
    });
    match plan {
        ExecPlan::StreamParallel { threads } => {
            let chunk_sig = parallel::reduce_signature(spec, eff_len, &point, threads);
            mul_assign(spec, &mut out, &chunk_sig);
        }
        // LaneFused never arises for batch = 1; run the reference sweep.
        ExecPlan::Scalar | ExecPlan::LaneFused { .. } => {
            let mut ws = Workspace::<E>::new(spec);
            sig_of_points(spec, eff_len, point, &mut out, &mut ws);
        }
    }
    Ok(out)
}

/// Stream mode (§5.5 "expanding intervals"): returns the `(stream-1) *
/// sig_len` buffer of prefix signatures
/// `Sig(x_1..x_2), Sig(x_1..x_3), ..., Sig(x_1..x_L)`, computed in one
/// O(L) sweep — all earlier signatures are byproducts of the last.
pub fn signature_stream(path: &[f32], stream: usize, spec: &SigSpec) -> Vec<f32> {
    signature_stream_with(path, stream, spec, &SigConfig::serial()).expect("valid path")
}

/// Stream mode with options. `inverse` is not supported in stream mode
/// (prefixes of the reversed path are suffixes of the original; use the
/// `Path` class for arbitrary intervals instead) and returns an error.
pub fn signature_stream_with(
    path: &[f32],
    stream: usize,
    spec: &SigSpec,
    cfg: &SigConfig,
) -> anyhow::Result<Vec<f32>> {
    anyhow::ensure!(!cfg.inverse, "stream mode does not support inverse; see Path");
    // Same validation as `signature_with` — including the basepoint /
    // initial channel counts, which the increment loop below would
    // otherwise hit as an index-out-of-bounds panic.
    let eff_len = check_path_with(path, stream, spec, cfg)?;
    let d = spec.d();
    let point = |i: usize| -> &[f32] {
        match &cfg.basepoint {
            Some(bp) => {
                if i == 0 {
                    bp.as_slice()
                } else {
                    &path[(i - 1) * d..i * d]
                }
            }
            None => &path[i * d..(i + 1) * d],
        }
    };
    let len = spec.sig_len();
    let n_out = eff_len - 1;
    let mut out = vec![0.0f32; n_out * len];
    let mut ws = Workspace::new(spec);
    let mut cur = match &cfg.initial {
        Some(init) => init.clone(),
        None => spec.zeros(),
    };
    let mut z = vec![0.0f32; d];
    for i in 1..eff_len {
        let prev = point(i - 1);
        let now = point(i);
        for c in 0..d {
            z[c] = now[c] - prev[c];
        }
        fused_mexp(spec, &mut cur, &z, &mut ws);
        out[(i - 1) * len..i * len].copy_from_slice(&cur);
    }
    Ok(out)
}

/// Batched signature over a `(batch, stream, d)` buffer. Returns
/// `(batch, sig_len)`.
///
/// Runs the lane-fused engine: blocks of up to the shape's lane width
/// ([`crate::exec::lane_width`], at most [`MAX_LANE_WIDTH`]) paths
/// advance together through one interleaved fused sweep per increment
/// (vectorised across the batch), and blocks distribute over `threads`
/// (§5.1's first level of parallelism). Shapes are validated up front —
/// `stream < 2` or a wrong buffer length is an `Err`, never a worker
/// panic. For `batch >= 2` results are bitwise identical to serial
/// per-path [`signature`] calls; a batch of 1 instead delegates to
/// [`signature_with`], whose chunked stream reduction engages for
/// `threads > 1` on long streams (same values to rounding, not bitwise).
pub fn signature_batch<E: Elem>(
    paths: &[E],
    batch: usize,
    stream: usize,
    spec: &SigSpec,
    threads: usize,
) -> anyhow::Result<Vec<E>> {
    let cfg = SigConfig { threads, ..SigConfig::serial() };
    signature_batch_with(paths, batch, stream, spec, &cfg)
}

/// Batched signature with full options. The basepoint / initial / inverse
/// configuration applies to every path in the batch; `cfg.threads` workers
/// share the lane blocks. Strategy selection goes through
/// [`crate::exec::ExecPlanner`]; use [`signature_batch_planned`] to
/// execute a plan chosen elsewhere (the serving layer does, so a lone
/// flushed row always runs the scalar reference sweep).
pub fn signature_batch_with<E: Elem>(
    paths: &[E],
    batch: usize,
    stream: usize,
    spec: &SigSpec,
    cfg: &SigConfig,
) -> anyhow::Result<Vec<E>> {
    // Planning needs only the shape (pure arithmetic); all validation
    // lives in `signature_batch_planned`, which errors before executing
    // a plan derived from malformed inputs.
    let plan = ExecPlanner::new(cfg.threads).plan_forward(&WorkShape {
        batch,
        points: cfg.effective_len(stream),
        d: spec.d(),
        depth: spec.depth(),
        dtype: E::PRECISION,
    });
    signature_batch_planned(paths, batch, stream, spec, cfg, plan)
}

/// Execute a batched signature under an explicit [`ExecPlan`].
///
/// Every plan computes the same per-path values for the same inputs
/// (`Scalar` and `LaneFused` are bitwise identical; `StreamParallel`
/// re-associates ⊠ inside each path and agrees to rounding). Callers
/// normally go through [`signature_batch_with`], which asks the planner;
/// the coordinator's microbatch backend passes its serving plan here, and
/// the batched logsignature ([`crate::logsignature::batch`]) executes the
/// same plans through this shared executor before its per-lane epilogue.
pub fn signature_batch_planned<E: Elem>(
    paths: &[E],
    batch: usize,
    stream: usize,
    spec: &SigSpec,
    cfg: &SigConfig,
    plan: ExecPlan,
) -> anyhow::Result<Vec<E>> {
    let d = spec.d();
    anyhow::ensure!(batch >= 1, "need at least one path in the batch");
    anyhow::ensure!(
        paths.len() == batch * stream * d,
        "batch buffer has {} values, expected batch({batch}) * stream({stream}) * channels({d}) = {}",
        paths.len(),
        batch * stream * d
    );
    // Lanes share one shape, so validating the first path (plus the shared
    // basepoint/initial) validates the whole batch.
    let eff_len = check_path_with(&paths[..stream * d], stream, spec, cfg)?;
    let len = spec.sig_len();
    let path_len = stream * d;
    let threads = cfg.threads.max(1);
    let block = match plan {
        ExecPlan::LaneFused { block } if batch >= 2 => block.clamp(1, MAX_LANE_WIDTH),
        ExecPlan::StreamParallel { threads: t } => {
            // Per-path dispatch with stream parallelism inside each path.
            let inner = SigConfig { threads: t, ..cfg.clone() };
            return batch_per_path(paths, batch, stream, spec, &inner, threads);
        }
        _ => {
            // Scalar: serial reference sweep per path, paths over threads.
            let inner = SigConfig { threads: 1, ..cfg.clone() };
            return batch_per_path(paths, batch, stream, spec, &inner, threads);
        }
    };
    let basepoint: Option<Vec<E>> =
        cfg.basepoint.as_ref().map(|bp| bp.iter().map(|&v| E::from_f32(v)).collect());
    let initial: Option<Vec<E>> =
        cfg.initial.as_ref().map(|init| init.iter().map(|&v| E::from_f32(v)).collect());
    let point = |lane: usize, i: usize| -> &[E] {
        let i = if cfg.inverse { eff_len - 1 - i } else { i };
        let base = lane * path_len;
        match &basepoint {
            Some(bp) => {
                if i == 0 {
                    bp.as_slice()
                } else {
                    &paths[base + (i - 1) * d..base + i * d]
                }
            }
            None => &paths[base + i * d..base + (i + 1) * d],
        }
    };
    let n_blocks = batch.div_ceil(block);
    let blocks =
        crate::substrate::pool::parallel_map_indexed(n_blocks, threads, |bi| {
            let l0 = bi * block;
            let lanes = block.min(batch - l0);
            let mut ws = BatchWorkspace::<E>::new(spec, lanes);
            let mut state = vec![E::ZERO; len * lanes];
            if let Some(init) = &initial {
                for (i, &v) in init.iter().enumerate() {
                    state[i * lanes..(i + 1) * lanes].fill(v);
                }
            }
            let mut z = vec![E::ZERO; d * lanes];
            for i in 1..eff_len {
                for l in 0..lanes {
                    let prev = point(l0 + l, i - 1);
                    let cur = point(l0 + l, i);
                    for c in 0..d {
                        z[c * lanes + l] = cur[c] - prev[c];
                    }
                }
                fused_mexp_batch(spec, &mut state, &z, &mut ws);
            }
            let mut rows = vec![E::ZERO; lanes * len];
            for l in 0..lanes {
                unpack_lane(len, lanes, &state, l, &mut rows[l * len..(l + 1) * len]);
            }
            rows
        });
    let mut out = vec![E::ZERO; batch * len];
    for (bi, rows) in blocks.into_iter().enumerate() {
        let o = bi * block * len;
        out[o..o + rows.len()].copy_from_slice(&rows);
    }
    Ok(out)
}

/// Per-path execution of a batch: each path runs [`signature_with`] under
/// `inner` (whose `threads` is the *within-path* budget), with paths
/// distributed over `outer_threads`.
fn batch_per_path<E: Elem>(
    paths: &[E],
    batch: usize,
    stream: usize,
    spec: &SigSpec,
    inner: &SigConfig,
    outer_threads: usize,
) -> anyhow::Result<Vec<E>> {
    let plen = stream * spec.d();
    let len = spec.sig_len();
    let rows = crate::substrate::pool::parallel_map_indexed(batch, outer_threads, |b| {
        signature_with(&paths[b * plen..(b + 1) * plen], stream, spec, inner)
    });
    let mut out = vec![E::ZERO; batch * len];
    for (b, row) in rows.into_iter().enumerate() {
        out[b * len..(b + 1) * len].copy_from_slice(&row?);
    }
    Ok(out)
}

/// The inverted signature as a standalone convenience (§5.4).
pub fn inverted_signature(path: &[f32], stream: usize, spec: &SigSpec) -> Vec<f32> {
    let cfg = SigConfig { inverse: true, ..SigConfig::serial() };
    signature_with(path, stream, spec, &cfg).expect("valid path")
}

/// Test/bench oracle: inverted signature via the generic group inverse
/// rather than path reversal.
pub fn inverted_signature_via_inverse(path: &[f32], stream: usize, spec: &SigSpec) -> Vec<f32> {
    let sig = signature(path, stream, spec);
    let mut out = spec.zeros();
    inverse_into(spec, &sig, &mut out);
    out
}

/// Signature of a two-point path = exp of the increment (§2.2); exposed
/// for tests and the Path class. Panics on mismatched channel counts; use
/// [`two_point_signature_into`] for the fallible, allocation-free variant.
pub fn two_point_signature<E: Elem>(a: &[E], b: &[E], spec: &SigSpec) -> Vec<E> {
    let mut out = spec.zeros_elem::<E>();
    two_point_signature_into(a, b, spec, &mut out).expect("points match the spec");
    out
}

/// Allocation-free `Sig((a, b)) = exp(b - a)` into a caller buffer: the
/// increment is staged directly in `out`'s level 1 and exponentiated in
/// place, so the O(1) hot paths (`Path` adjacent-interval queries, the
/// streaming serving feed) allocate nothing per call.
pub fn two_point_signature_into<E: Elem>(
    a: &[E],
    b: &[E],
    spec: &SigSpec,
    out: &mut [E],
) -> anyhow::Result<()> {
    let d = spec.d();
    anyhow::ensure!(
        a.len() == d && b.len() == d,
        "points have {} / {} channels, expected {d}",
        a.len(),
        b.len()
    );
    anyhow::ensure!(
        out.len() == spec.sig_len(),
        "output buffer has {} values, expected sig_len {}",
        out.len(),
        spec.sig_len()
    );
    for ((o, &x), &y) in out[..d].iter_mut().zip(b).zip(a) {
        *o = x - y;
    }
    exp_in_place(spec, out);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::propcheck::{assert_close, property};
    use crate::substrate::rng::Rng;
    use crate::ta::{exp, mul};

    fn random_path(rng: &mut Rng, stream: usize, d: usize) -> Vec<f32> {
        // Brownian-ish increments keep signatures numerically tame.
        let mut p = vec![0.0f32; stream * d];
        for i in 1..stream {
            for c in 0..d {
                p[i * d + c] = p[(i - 1) * d + c] + rng.normal_f32() * 0.3;
            }
        }
        p
    }

    #[test]
    fn two_point_path_is_exponential() {
        let spec = SigSpec::new(3, 4).unwrap();
        let path = [0.1f32, 0.2, 0.3, 1.1, 0.0, -0.3];
        let sig = signature(&path, 2, &spec);
        let z = [1.0f32, -0.2, -0.6];
        assert_close(&sig, &exp(&spec, &z), 1e-5, 1e-7);
    }

    #[test]
    fn chens_identity() {
        // Sig(x_1..x_L) = Sig(x_1..x_j) ⊠ Sig(x_j..x_L)  (eq. 2).
        property("Chen's identity", 30, |g| {
            let d = g.usize_in(1, 4);
            let n = g.usize_in(1, 5);
            let stream = g.usize_in(3, 20);
            let j = g.usize_in(1, stream - 2); // split point (0-based)
            g.label(format!("d={d} n={n} stream={stream} j={j}"));
            let spec = SigSpec::new(d, n).unwrap();
            let path = random_path(g.rng(), stream, d);
            let full = signature(&path, stream, &spec);
            let left = signature(&path[..(j + 1) * d], j + 1, &spec);
            let right = signature(&path[j * d..], stream - j, &spec);
            assert_close(&mul(&spec, &left, &right), &full, 2e-3, 1e-4);
        });
    }

    #[test]
    fn translation_invariance() {
        // Signatures depend only on increments.
        property("translation invariance", 20, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let stream = g.usize_in(2, 12);
            let spec = SigSpec::new(d, n).unwrap();
            let path = random_path(g.rng(), stream, d);
            let shift = g.normal_vec(d, 1.0);
            let shifted: Vec<f32> = path
                .iter()
                .enumerate()
                .map(|(i, &v)| v + shift[i % d])
                .collect();
            assert_close(
                &signature(&shifted, stream, &spec),
                &signature(&path, stream, &spec),
                1e-4,
                1e-5,
            );
        });
    }

    #[test]
    fn reparameterisation_invariance() {
        // Inserting a redundant midpoint on a straight segment changes
        // nothing (Definition 4's choice of timestamps is immaterial).
        let spec = SigSpec::new(2, 4).unwrap();
        let path = [0.0f32, 0.0, 1.0, 2.0, 3.0, -1.0];
        let sig = signature(&path, 3, &spec);
        let with_mid = [0.0f32, 0.0, 0.5, 1.0, 1.0, 2.0, 3.0, -1.0];
        let sig_mid = signature(&with_mid, 4, &spec);
        assert_close(&sig_mid, &sig, 1e-5, 1e-6);
    }

    #[test]
    fn stream_mode_matches_prefix_recomputation() {
        property("stream == prefixes", 15, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let stream = g.usize_in(2, 12);
            g.label(format!("d={d} n={n} stream={stream}"));
            let spec = SigSpec::new(d, n).unwrap();
            let path = random_path(g.rng(), stream, d);
            let st = signature_stream(&path, stream, &spec);
            let len = spec.sig_len();
            for j in 2..=stream {
                let direct = signature(&path[..j * d], j, &spec);
                assert_close(&st[(j - 2) * len..(j - 1) * len], &direct, 1e-3, 1e-4);
            }
        });
    }

    #[test]
    fn basepoint_matches_explicit_prepend() {
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(21);
        let path = random_path(&mut rng, 5, 2);
        let bp = vec![0.25f32, -0.5];
        let cfg = SigConfig { basepoint: Some(bp.clone()), ..SigConfig::serial() };
        let with_bp = signature_with(&path, 5, &spec, &cfg).unwrap();
        let mut prepended = bp;
        prepended.extend_from_slice(&path);
        assert_close(&with_bp, &signature(&prepended, 6, &spec), 1e-5, 1e-6);
    }

    #[test]
    fn initial_matches_combine() {
        // signature(second_half, initial=Sig(first_half)) == Sig(whole):
        // the "keeping the signature up-to-date" use (§5.5, eq. 7).
        let spec = SigSpec::new(3, 3).unwrap();
        let mut rng = Rng::new(33);
        let path = random_path(&mut rng, 10, 3);
        let full = signature(&path, 10, &spec);
        let first = signature(&path[..6 * 3], 6, &spec);
        let cfg = SigConfig { initial: Some(first), ..SigConfig::serial() };
        let resumed = signature_with(&path[5 * 3..], 5, &spec, &cfg).unwrap();
        assert_close(&resumed, &full, 1e-4, 1e-5);
    }

    #[test]
    fn inverse_equals_reversed_path() {
        property("Sig^{-1} == Sig(reversed)", 15, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let stream = g.usize_in(2, 10);
            let spec = SigSpec::new(d, n).unwrap();
            let path = random_path(g.rng(), stream, d);
            let rev: Vec<f32> = (0..stream)
                .rev()
                .flat_map(|i| path[i * d..(i + 1) * d].to_vec())
                .collect();
            let cfg = SigConfig { inverse: true, ..SigConfig::serial() };
            let inv = signature_with(&path, stream, &spec, &cfg).unwrap();
            assert_close(&inv, &signature(&rev, stream, &spec), 1e-5, 1e-6);
            // And it matches the algebraic group inverse (§5.4).
            let via_algebra = inverted_signature_via_inverse(&path, stream, &spec);
            assert_close(&inv, &via_algebra, 2e-3, 1e-4);
        });
    }

    #[test]
    fn parallel_matches_serial() {
        property("parallel == serial", 10, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let stream = g.usize_in(17, 200);
            let threads = g.usize_in(2, 6);
            g.label(format!("d={d} n={n} stream={stream} t={threads}"));
            let spec = SigSpec::new(d, n).unwrap();
            let path = random_path(g.rng(), stream, d);
            let serial = signature(&path, stream, &spec);
            let cfg = SigConfig::parallel(threads);
            let par = signature_with(&path, stream, &spec, &cfg).unwrap();
            assert_close(&par, &serial, 2e-3, 1e-4);
        });
    }

    #[test]
    fn batch_matches_per_sample() {
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(8);
        let (b, stream) = (5, 7);
        let mut batchbuf = vec![0.0f32; b * stream * 2];
        for i in 0..b {
            let p = random_path(&mut rng, stream, 2);
            batchbuf[i * stream * 2..(i + 1) * stream * 2].copy_from_slice(&p);
        }
        let out = signature_batch(&batchbuf, b, stream, &spec, 3).unwrap();
        let len = spec.sig_len();
        for i in 0..b {
            let single = signature(&batchbuf[i * stream * 2..(i + 1) * stream * 2], stream, &spec);
            assert_close(&out[i * len..(i + 1) * len], &single, 1e-6, 1e-7);
        }
    }

    #[test]
    fn errors_on_bad_shapes() {
        let spec = SigSpec::new(2, 3).unwrap();
        assert!(signature_with(&[0.0f32; 5], 2, &spec, &SigConfig::serial()).is_err()); // wrong len
        assert!(signature_with(&[0.0f32; 2], 1, &spec, &SigConfig::serial()).is_err()); // 1 point
        let cfg = SigConfig { basepoint: Some(vec![0.0; 3]), ..SigConfig::serial() };
        assert!(signature_with(&[0.0f32; 4], 2, &spec, &cfg).is_err()); // bad basepoint
        let cfg = SigConfig { initial: Some(vec![0.0; 3]), ..SigConfig::serial() };
        assert!(signature_with(&[0.0f32; 4], 2, &spec, &cfg).is_err()); // bad initial
        // A single point plus basepoint is fine.
        let cfg = SigConfig { basepoint: Some(vec![0.0; 2]), ..SigConfig::serial() };
        assert!(signature_with(&[1.0f32, 2.0], 1, &spec, &cfg).is_ok());
    }

    #[test]
    fn stream_mode_errors_on_bad_shapes() {
        // Regression: a basepoint with too few channels used to panic with
        // an index-out-of-bounds inside the increment loop instead of
        // returning Err; stream mode now validates through check_path_with
        // exactly like signature_with.
        let spec = SigSpec::new(2, 3).unwrap();
        let path = vec![0.0f32; 4 * 2];
        let short_bp = SigConfig { basepoint: Some(vec![0.0; 1]), ..SigConfig::serial() };
        assert!(signature_stream_with(&path, 4, &spec, &short_bp).is_err());
        let long_bp = SigConfig { basepoint: Some(vec![0.0; 3]), ..SigConfig::serial() };
        assert!(signature_stream_with(&path, 4, &spec, &long_bp).is_err());
        let bad_init = SigConfig { initial: Some(vec![0.0; 3]), ..SigConfig::serial() };
        assert!(signature_stream_with(&path, 4, &spec, &bad_init).is_err());
        assert!(signature_stream_with(&path, 5, &spec, &SigConfig::serial()).is_err()); // wrong len
        assert!(signature_stream_with(&path[..2], 1, &spec, &SigConfig::serial()).is_err()); // 1 point
        // A valid basepoint still works and matches explicit prepending.
        let bp = vec![0.25f32, -0.5];
        let cfg = SigConfig { basepoint: Some(bp.clone()), ..SigConfig::serial() };
        let with_bp = signature_stream_with(&path, 4, &spec, &cfg).unwrap();
        let mut prepended = bp;
        prepended.extend_from_slice(&path);
        let direct = signature_stream(&prepended, 5, &spec);
        assert_close(&with_bp, &direct, 1e-6, 1e-7);
    }

    #[test]
    fn batch_lane_engine_is_bitwise_per_path() {
        // The lane-fused sweep performs each lane's ops in the scalar
        // order, so batched == per-path bit-for-bit — including a ragged
        // tail block (37 = 2 * LANE_BLOCK + 5 lanes).
        let spec = SigSpec::new(3, 3).unwrap();
        let mut rng = Rng::new(41);
        let (b, stream) = (2 * super::LANE_BLOCK + 5, 9);
        let plen = stream * 3;
        let mut paths = vec![0.0f32; b * plen];
        for i in 0..b {
            let p = random_path(&mut rng, stream, 3);
            paths[i * plen..(i + 1) * plen].copy_from_slice(&p);
        }
        let out = signature_batch(&paths, b, stream, &spec, 3).unwrap();
        let len = spec.sig_len();
        for i in 0..b {
            let single = signature(&paths[i * plen..(i + 1) * plen], stream, &spec);
            assert_eq!(&out[i * len..(i + 1) * len], single.as_slice(), "lane {i}");
        }
    }

    #[test]
    fn batch_lane_engine_is_bitwise_per_path_in_f64() {
        // The precision axis: the same lane/scalar parity holds when the
        // whole pipeline runs in f64, including at d beyond the mono
        // window (d = 9 > LANE_VJP_MAX_D exercises the runtime-d bodies).
        for (d, depth) in [(3usize, 3usize), (9, 3)] {
            let spec = SigSpec::new(d, depth).unwrap();
            let mut rng = Rng::new(47 + d as u64);
            let (b, stream) = (super::LANE_BLOCK + 3, 6);
            let plen = stream * d;
            let f32_paths = random_path(&mut rng, b * stream, d);
            let paths: Vec<f64> = f32_paths.iter().map(|&v| v as f64).collect();
            let out = signature_batch(&paths, b, stream, &spec, 2).unwrap();
            let len = spec.sig_len();
            for i in 0..b {
                let single = signature(&paths[i * plen..(i + 1) * plen], stream, &spec);
                assert_eq!(&out[i * len..(i + 1) * len], single.as_slice(), "d={d} lane {i}");
            }
        }
    }

    #[test]
    fn batch_with_options_is_bitwise_per_path() {
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(43);
        let (b, stream) = (6, 7);
        let plen = stream * 2;
        let mut paths = vec![0.0f32; b * plen];
        for i in 0..b {
            let p = random_path(&mut rng, stream, 2);
            paths[i * plen..(i + 1) * plen].copy_from_slice(&p);
        }
        let init = signature(&random_path(&mut rng, 4, 2), 4, &spec);
        for inverse in [false, true] {
            let cfg = SigConfig {
                basepoint: Some(vec![0.3, -0.1]),
                initial: Some(init.clone()),
                inverse,
                ..SigConfig::serial()
            };
            let out = signature_batch_with(&paths, b, stream, &spec, &cfg).unwrap();
            let len = spec.sig_len();
            for i in 0..b {
                let single =
                    signature_with(&paths[i * plen..(i + 1) * plen], stream, &spec, &cfg).unwrap();
                assert_eq!(&out[i * len..(i + 1) * len], single.as_slice());
            }
        }
    }

    #[test]
    fn batch_errors_instead_of_panicking() {
        // Regression: signature_batch used to call the panicking
        // `signature` inside worker threads, so stream < 2 crossed a
        // thread boundary as a panic. All malformed shapes are now Err.
        let spec = SigSpec::new(2, 3).unwrap();
        assert!(signature_batch(&[0.0f32; 4], 2, 1, &spec, 2).is_err()); // stream < 2
        assert!(signature_batch(&[0.0f32; 4], 0, 2, &spec, 2).is_err()); // empty batch
        assert!(signature_batch(&[0.0f32; 5], 1, 2, &spec, 2).is_err()); // wrong buffer
        let bad_bp = SigConfig { basepoint: Some(vec![0.0; 1]), ..SigConfig::serial() };
        assert!(signature_batch_with(&[0.0f32; 8], 2, 2, &spec, &bad_bp).is_err());
    }

    #[test]
    fn two_point_into_matches_and_validates() {
        let spec = SigSpec::new(3, 4).unwrap();
        let a = [0.1f32, 0.2, 0.3];
        let b = [1.1f32, 0.0, -0.3];
        let direct = two_point_signature(&a, &b, &spec);
        let mut out = vec![1.0f32; spec.sig_len()]; // dirty buffer: every entry must be overwritten
        two_point_signature_into(&a, &b, &spec, &mut out).unwrap();
        assert_eq!(out, direct);
        assert_close(&out, &exp(&spec, &[1.0, -0.2, -0.6]), 1e-5, 1e-7);
        // Shape mismatches are errors, not slice panics.
        assert!(two_point_signature_into(&a[..2], &b, &spec, &mut out).is_err());
        assert!(two_point_signature_into(&a, &b[..1], &spec, &mut out).is_err());
        assert!(two_point_signature_into(&a, &b, &spec, &mut out[..2]).is_err());
    }
}
