//! Signature forward passes.
//!
//! The core loop is eq. (3) written as a reduction with respect to the
//! fused multiply-exponentiate (§4.1, §5.1): one `exp` for the first
//! increment, then one fused `⊠ exp` per remaining increment. Stream mode
//! (§5.5 "expanding intervals") emits every prefix signature for free.
//! Parallel mode splits the stream into chunks — ⊠ is associative — and
//! combines chunk signatures (§5.1).

use super::SigConfig;
use crate::parallel;
use crate::ta::exp::exp_into;
use crate::ta::fused::fused_mexp;
use crate::ta::inverse::inverse_into;
use crate::ta::mul::mul_assign;
use crate::ta::{SigSpec, Workspace};

/// Validate a `(stream, d)` path buffer against the spec.
fn check_path(path: &[f32], stream: usize, spec: &SigSpec) -> anyhow::Result<()> {
    anyhow::ensure!(
        path.len() == stream * spec.d(),
        "path buffer has {} values, expected stream({}) * channels({})",
        path.len(),
        stream,
        spec.d()
    );
    Ok(())
}

/// Validate a path buffer *and* the config's basepoint/initial shapes;
/// returns the effective point count (incl. basepoint). Shared by the
/// forward pass and the backward pass (whose parallel branch never calls
/// [`signature_with`], so it must not rely on the forward for checks).
pub(crate) fn check_path_with(
    path: &[f32],
    stream: usize,
    spec: &SigSpec,
    cfg: &SigConfig,
) -> anyhow::Result<usize> {
    check_path(path, stream, spec)?;
    let d = spec.d();
    let eff_len = cfg.effective_len(stream);
    anyhow::ensure!(
        eff_len >= 2,
        "a path must have at least two points (incl. basepoint) to define a signature, got {}",
        eff_len
    );
    if let Some(bp) = &cfg.basepoint {
        anyhow::ensure!(bp.len() == d, "basepoint has {} channels, expected {d}", bp.len());
    }
    if let Some(init) = &cfg.initial {
        anyhow::ensure!(
            init.len() == spec.sig_len(),
            "initial signature has {} values, expected {}",
            init.len(),
            spec.sig_len()
        );
    }
    Ok(eff_len)
}

/// Serial signature of the increments `z_i = p_{i+1} - p_i` of a point
/// view. `points(i)` must yield the i-th point as a slice of length d.
/// Writes into `out` (which must be zeroed = identity, or hold `initial`).
fn sig_of_points<'a>(
    spec: &SigSpec,
    n_points: usize,
    points: impl Fn(usize) -> &'a [f32],
    out: &mut [f32],
    ws: &mut Workspace,
) {
    let d = spec.d();
    let mut z = vec![0.0f32; d];
    for i in 1..n_points {
        let prev = points(i - 1);
        let cur = points(i);
        for c in 0..d {
            z[c] = cur[c] - prev[c];
        }
        fused_mexp(spec, out, &z, ws);
    }
}

/// `Sig^N(path)` — the plain signature transform of one path of
/// `stream >= 2` points in `R^d`. Panics on shape mismatch (use
/// [`signature_with`] for a fallible, configurable version).
pub fn signature(path: &[f32], stream: usize, spec: &SigSpec) -> Vec<f32> {
    signature_with(path, stream, spec, &SigConfig::serial()).expect("valid path")
}

/// Signature with full options (basepoint / initial / inverse / threads).
pub fn signature_with(
    path: &[f32],
    stream: usize,
    spec: &SigSpec,
    cfg: &SigConfig,
) -> anyhow::Result<Vec<f32>> {
    let d = spec.d();
    let eff_len = check_path_with(path, stream, spec, cfg)?;

    // Materialise the effective point sequence accessor (with basepoint and
    // possible reversal for the inverted signature, §5.4).
    let point = |i: usize| -> &[f32] {
        let i = if cfg.inverse { eff_len - 1 - i } else { i };
        match &cfg.basepoint {
            Some(bp) => {
                if i == 0 {
                    bp.as_slice()
                } else {
                    &path[(i - 1) * d..i * d]
                }
            }
            None => &path[i * d..(i + 1) * d],
        }
    };

    let mut out = match &cfg.initial {
        Some(init) => init.clone(),
        None => spec.zeros(),
    };
    let threads = cfg.threads.max(1);
    if threads == 1 || eff_len < 16 {
        let mut ws = Workspace::new(spec);
        sig_of_points(spec, eff_len, point, &mut out, &mut ws);
    } else {
        let chunk_sig = parallel::reduce_signature(spec, eff_len, &point, threads);
        mul_assign(spec, &mut out, &chunk_sig);
    }
    Ok(out)
}

/// Stream mode (§5.5 "expanding intervals"): returns the `(stream-1) *
/// sig_len` buffer of prefix signatures
/// `Sig(x_1..x_2), Sig(x_1..x_3), ..., Sig(x_1..x_L)`, computed in one
/// O(L) sweep — all earlier signatures are byproducts of the last.
pub fn signature_stream(path: &[f32], stream: usize, spec: &SigSpec) -> Vec<f32> {
    signature_stream_with(path, stream, spec, &SigConfig::serial()).expect("valid path")
}

/// Stream mode with options. `inverse` is not supported in stream mode
/// (prefixes of the reversed path are suffixes of the original; use the
/// `Path` class for arbitrary intervals instead) and returns an error.
pub fn signature_stream_with(
    path: &[f32],
    stream: usize,
    spec: &SigSpec,
    cfg: &SigConfig,
) -> anyhow::Result<Vec<f32>> {
    check_path(path, stream, spec)?;
    anyhow::ensure!(!cfg.inverse, "stream mode does not support inverse; see Path");
    let d = spec.d();
    let eff_len = cfg.effective_len(stream);
    anyhow::ensure!(eff_len >= 2, "need at least two points, got {eff_len}");
    let point = |i: usize| -> &[f32] {
        match &cfg.basepoint {
            Some(bp) => {
                if i == 0 {
                    bp.as_slice()
                } else {
                    &path[(i - 1) * d..i * d]
                }
            }
            None => &path[i * d..(i + 1) * d],
        }
    };
    let len = spec.sig_len();
    let n_out = eff_len - 1;
    let mut out = vec![0.0f32; n_out * len];
    let mut ws = Workspace::new(spec);
    let mut cur = match &cfg.initial {
        Some(init) => {
            anyhow::ensure!(init.len() == len, "bad initial length");
            init.clone()
        }
        None => spec.zeros(),
    };
    let mut z = vec![0.0f32; d];
    for i in 1..eff_len {
        let prev = point(i - 1);
        let now = point(i);
        for c in 0..d {
            z[c] = now[c] - prev[c];
        }
        fused_mexp(spec, &mut cur, &z, &mut ws);
        out[(i - 1) * len..i * len].copy_from_slice(&cur);
    }
    Ok(out)
}

/// Batched signature over a `(batch, stream, d)` buffer, parallel over the
/// batch dimension (§5.1's first level of parallelism). Returns
/// `(batch, sig_len)`.
pub fn signature_batch(
    paths: &[f32],
    batch: usize,
    stream: usize,
    spec: &SigSpec,
    threads: usize,
) -> anyhow::Result<Vec<f32>> {
    anyhow::ensure!(
        paths.len() == batch * stream * spec.d(),
        "batch buffer has {} values, expected {}",
        paths.len(),
        batch * stream * spec.d()
    );
    let len = spec.sig_len();
    let path_len = stream * spec.d();
    let results = crate::substrate::pool::parallel_map_indexed(batch, threads, |b| {
        signature(&paths[b * path_len..(b + 1) * path_len], stream, spec)
    });
    let mut out = vec![0.0f32; batch * len];
    for (b, sig) in results.into_iter().enumerate() {
        out[b * len..(b + 1) * len].copy_from_slice(&sig);
    }
    Ok(out)
}

/// The inverted signature as a standalone convenience (§5.4).
pub fn inverted_signature(path: &[f32], stream: usize, spec: &SigSpec) -> Vec<f32> {
    let cfg = SigConfig { inverse: true, ..SigConfig::serial() };
    signature_with(path, stream, spec, &cfg).expect("valid path")
}

/// Test/bench oracle: inverted signature via the generic group inverse
/// rather than path reversal.
pub fn inverted_signature_via_inverse(path: &[f32], stream: usize, spec: &SigSpec) -> Vec<f32> {
    let sig = signature(path, stream, spec);
    let mut out = spec.zeros();
    inverse_into(spec, &sig, &mut out);
    out
}

/// Signature of a two-point path = exp of the increment (§2.2); exposed
/// for tests and the Path class.
pub fn two_point_signature(a: &[f32], b: &[f32], spec: &SigSpec) -> Vec<f32> {
    let z: Vec<f32> = b.iter().zip(a).map(|(&x, &y)| x - y).collect();
    let mut out = spec.zeros();
    exp_into(spec, &z, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::propcheck::{assert_close, property};
    use crate::substrate::rng::Rng;
    use crate::ta::{exp, mul};

    fn random_path(rng: &mut Rng, stream: usize, d: usize) -> Vec<f32> {
        // Brownian-ish increments keep signatures numerically tame.
        let mut p = vec![0.0f32; stream * d];
        for i in 1..stream {
            for c in 0..d {
                p[i * d + c] = p[(i - 1) * d + c] + rng.normal_f32() * 0.3;
            }
        }
        p
    }

    #[test]
    fn two_point_path_is_exponential() {
        let spec = SigSpec::new(3, 4).unwrap();
        let path = [0.1f32, 0.2, 0.3, 1.1, 0.0, -0.3];
        let sig = signature(&path, 2, &spec);
        let z = [1.0f32, -0.2, -0.6];
        assert_close(&sig, &exp(&spec, &z), 1e-5, 1e-7);
    }

    #[test]
    fn chens_identity() {
        // Sig(x_1..x_L) = Sig(x_1..x_j) ⊠ Sig(x_j..x_L)  (eq. 2).
        property("Chen's identity", 30, |g| {
            let d = g.usize_in(1, 4);
            let n = g.usize_in(1, 5);
            let stream = g.usize_in(3, 20);
            let j = g.usize_in(1, stream - 2); // split point (0-based)
            g.label(format!("d={d} n={n} stream={stream} j={j}"));
            let spec = SigSpec::new(d, n).unwrap();
            let path = random_path(g.rng(), stream, d);
            let full = signature(&path, stream, &spec);
            let left = signature(&path[..(j + 1) * d], j + 1, &spec);
            let right = signature(&path[j * d..], stream - j, &spec);
            assert_close(&mul(&spec, &left, &right), &full, 2e-3, 1e-4);
        });
    }

    #[test]
    fn translation_invariance() {
        // Signatures depend only on increments.
        property("translation invariance", 20, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let stream = g.usize_in(2, 12);
            let spec = SigSpec::new(d, n).unwrap();
            let path = random_path(g.rng(), stream, d);
            let shift = g.normal_vec(d, 1.0);
            let shifted: Vec<f32> = path
                .iter()
                .enumerate()
                .map(|(i, &v)| v + shift[i % d])
                .collect();
            assert_close(
                &signature(&shifted, stream, &spec),
                &signature(&path, stream, &spec),
                1e-4,
                1e-5,
            );
        });
    }

    #[test]
    fn reparameterisation_invariance() {
        // Inserting a redundant midpoint on a straight segment changes
        // nothing (Definition 4's choice of timestamps is immaterial).
        let spec = SigSpec::new(2, 4).unwrap();
        let path = [0.0f32, 0.0, 1.0, 2.0, 3.0, -1.0];
        let sig = signature(&path, 3, &spec);
        let with_mid = [0.0f32, 0.0, 0.5, 1.0, 1.0, 2.0, 3.0, -1.0];
        let sig_mid = signature(&with_mid, 4, &spec);
        assert_close(&sig_mid, &sig, 1e-5, 1e-6);
    }

    #[test]
    fn stream_mode_matches_prefix_recomputation() {
        property("stream == prefixes", 15, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let stream = g.usize_in(2, 12);
            g.label(format!("d={d} n={n} stream={stream}"));
            let spec = SigSpec::new(d, n).unwrap();
            let path = random_path(g.rng(), stream, d);
            let st = signature_stream(&path, stream, &spec);
            let len = spec.sig_len();
            for j in 2..=stream {
                let direct = signature(&path[..j * d], j, &spec);
                assert_close(&st[(j - 2) * len..(j - 1) * len], &direct, 1e-3, 1e-4);
            }
        });
    }

    #[test]
    fn basepoint_matches_explicit_prepend() {
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(21);
        let path = random_path(&mut rng, 5, 2);
        let bp = vec![0.25f32, -0.5];
        let cfg = SigConfig { basepoint: Some(bp.clone()), ..SigConfig::serial() };
        let with_bp = signature_with(&path, 5, &spec, &cfg).unwrap();
        let mut prepended = bp;
        prepended.extend_from_slice(&path);
        assert_close(&with_bp, &signature(&prepended, 6, &spec), 1e-5, 1e-6);
    }

    #[test]
    fn initial_matches_combine() {
        // signature(second_half, initial=Sig(first_half)) == Sig(whole):
        // the "keeping the signature up-to-date" use (§5.5, eq. 7).
        let spec = SigSpec::new(3, 3).unwrap();
        let mut rng = Rng::new(33);
        let path = random_path(&mut rng, 10, 3);
        let full = signature(&path, 10, &spec);
        let first = signature(&path[..6 * 3], 6, &spec);
        let cfg = SigConfig { initial: Some(first), ..SigConfig::serial() };
        let resumed = signature_with(&path[5 * 3..], 5, &spec, &cfg).unwrap();
        assert_close(&resumed, &full, 1e-4, 1e-5);
    }

    #[test]
    fn inverse_equals_reversed_path() {
        property("Sig^{-1} == Sig(reversed)", 15, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let stream = g.usize_in(2, 10);
            let spec = SigSpec::new(d, n).unwrap();
            let path = random_path(g.rng(), stream, d);
            let rev: Vec<f32> = (0..stream)
                .rev()
                .flat_map(|i| path[i * d..(i + 1) * d].to_vec())
                .collect();
            let cfg = SigConfig { inverse: true, ..SigConfig::serial() };
            let inv = signature_with(&path, stream, &spec, &cfg).unwrap();
            assert_close(&inv, &signature(&rev, stream, &spec), 1e-5, 1e-6);
            // And it matches the algebraic group inverse (§5.4).
            let via_algebra = inverted_signature_via_inverse(&path, stream, &spec);
            assert_close(&inv, &via_algebra, 2e-3, 1e-4);
        });
    }

    #[test]
    fn parallel_matches_serial() {
        property("parallel == serial", 10, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let stream = g.usize_in(17, 200);
            let threads = g.usize_in(2, 6);
            g.label(format!("d={d} n={n} stream={stream} t={threads}"));
            let spec = SigSpec::new(d, n).unwrap();
            let path = random_path(g.rng(), stream, d);
            let serial = signature(&path, stream, &spec);
            let cfg = SigConfig::parallel(threads);
            let par = signature_with(&path, stream, &spec, &cfg).unwrap();
            assert_close(&par, &serial, 2e-3, 1e-4);
        });
    }

    #[test]
    fn batch_matches_per_sample() {
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(8);
        let (b, stream) = (5, 7);
        let mut batchbuf = vec![0.0f32; b * stream * 2];
        for i in 0..b {
            let p = random_path(&mut rng, stream, 2);
            batchbuf[i * stream * 2..(i + 1) * stream * 2].copy_from_slice(&p);
        }
        let out = signature_batch(&batchbuf, b, stream, &spec, 3).unwrap();
        let len = spec.sig_len();
        for i in 0..b {
            let single = signature(&batchbuf[i * stream * 2..(i + 1) * stream * 2], stream, &spec);
            assert_close(&out[i * len..(i + 1) * len], &single, 1e-6, 1e-7);
        }
    }

    #[test]
    fn errors_on_bad_shapes() {
        let spec = SigSpec::new(2, 3).unwrap();
        assert!(signature_with(&[0.0; 5], 2, &spec, &SigConfig::serial()).is_err()); // wrong len
        assert!(signature_with(&[0.0; 2], 1, &spec, &SigConfig::serial()).is_err()); // 1 point
        let cfg = SigConfig { basepoint: Some(vec![0.0; 3]), ..SigConfig::serial() };
        assert!(signature_with(&[0.0; 4], 2, &spec, &cfg).is_err()); // bad basepoint
        let cfg = SigConfig { initial: Some(vec![0.0; 3]), ..SigConfig::serial() };
        assert!(signature_with(&[0.0; 4], 2, &spec, &cfg).is_err()); // bad initial
        // A single point plus basepoint is fine.
        let cfg = SigConfig { basepoint: Some(vec![0.0; 2]), ..SigConfig::serial() };
        assert!(signature_with(&[1.0, 2.0], 1, &spec, &cfg).is_ok());
    }
}
