//! Combining signatures over adjacent intervals (§5.5): Chen's identity
//! lets `Sig(x_1..x_L)` be assembled from already-computed piece signatures
//! with single ⊠ operations, without re-iterating over the data. These are
//! Signatory's `signature_combine` / `multi_signature_combine`, with
//! handwritten VJPs.

use crate::ta::mul::{mul, mul_assign, mul_vjp};
use crate::ta::SigSpec;

/// `Sig(left interval) ⊠ Sig(right interval)` — eq. (2) applied to two
/// adjacent intervals.
pub fn signature_combine(spec: &SigSpec, sig1: &[f32], sig2: &[f32]) -> Vec<f32> {
    mul(spec, sig1, sig2)
}

/// VJP of [`signature_combine`]: accumulates into `g1`, `g2`.
pub fn signature_combine_vjp(
    spec: &SigSpec,
    sig1: &[f32],
    sig2: &[f32],
    g: &[f32],
    g1: &mut [f32],
    g2: &mut [f32],
) {
    mul_vjp(spec, sig1, sig2, g, g1, g2);
}

/// Combine many adjacent-interval signatures `(count, sig_len)` in order.
/// `threads > 1` uses an associative tree reduction.
pub fn multi_signature_combine(
    spec: &SigSpec,
    sigs: &[f32],
    count: usize,
    threads: usize,
) -> anyhow::Result<Vec<f32>> {
    let len = spec.sig_len();
    anyhow::ensure!(count >= 1, "need at least one signature");
    anyhow::ensure!(sigs.len() == count * len, "buffer has wrong length");
    if threads > 1 && count > 2 {
        return Ok(crate::parallel::tree_combine(spec, sigs, count, threads));
    }
    let mut acc = sigs[..len].to_vec();
    for i in 1..count {
        mul_assign(spec, &mut acc, &sigs[i * len..(i + 1) * len]);
    }
    Ok(acc)
}

/// VJP of [`multi_signature_combine`]: returns gradients with respect to
/// every input signature, shape `(count, sig_len)`.
///
/// Stores the forward prefix products (`count` signatures — combine counts
/// are small, unlike stream lengths, so storing is the right trade here).
pub fn multi_signature_combine_vjp(
    spec: &SigSpec,
    sigs: &[f32],
    count: usize,
    g: &[f32],
) -> anyhow::Result<Vec<f32>> {
    let len = spec.sig_len();
    anyhow::ensure!(count >= 1 && sigs.len() == count * len, "bad shapes");
    anyhow::ensure!(g.len() == len, "cotangent wrong length");
    if count == 1 {
        return Ok(g.to_vec());
    }
    // Forward prefixes: P_i = s_0 ⊠ ... ⊠ s_i, for i = 0..count-2 needed.
    let mut prefixes: Vec<Vec<f32>> = Vec::with_capacity(count - 1);
    let mut acc = sigs[..len].to_vec();
    prefixes.push(acc.clone());
    for i in 1..count - 1 {
        mul_assign(spec, &mut acc, &sigs[i * len..(i + 1) * len]);
        prefixes.push(acc.clone());
    }
    // Backward: out = P_{count-2} ⊠ s_{count-1}; unwind right-to-left.
    let mut grads = vec![0.0f32; count * len];
    let mut g_acc = g.to_vec();
    for i in (1..count).rev() {
        let left = &prefixes[i - 1];
        let right = &sigs[i * len..(i + 1) * len];
        let mut g_left = vec![0.0f32; len];
        {
            let g_right = &mut grads[i * len..(i + 1) * len];
            mul_vjp(spec, left, right, &g_acc, &mut g_left, g_right);
        }
        g_acc = g_left;
    }
    grads[..len].copy_from_slice(&g_acc);
    Ok(grads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::signature::forward::signature;
    use crate::substrate::propcheck::{assert_close, property};
    use crate::substrate::rng::Rng;

    fn random_path(rng: &mut Rng, stream: usize, d: usize) -> Vec<f32> {
        let mut p = vec![0.0f32; stream * d];
        for i in 1..stream {
            for c in 0..d {
                p[i * d + c] = p[(i - 1) * d + c] + rng.normal_f32() * 0.3;
            }
        }
        p
    }

    #[test]
    fn combine_reconstructs_full_signature() {
        property("combine == Chen", 20, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let pieces = g.usize_in(2, 5);
            g.label(format!("d={d} n={n} pieces={pieces}"));
            let spec = SigSpec::new(d, n).unwrap();
            // Build one path, split into `pieces` adjacent intervals
            // sharing endpoints.
            let seg_pts = 4usize;
            let stream = pieces * (seg_pts - 1) + 1;
            let path = random_path(g.rng(), stream, d);
            let len = spec.sig_len();
            let mut sigs = vec![0.0f32; pieces * len];
            for p in 0..pieces {
                let s = p * (seg_pts - 1);
                let sub = &path[s * d..(s + seg_pts) * d];
                sigs[p * len..(p + 1) * len].copy_from_slice(&signature(sub, seg_pts, &spec));
            }
            let combined = multi_signature_combine(&spec, &sigs, pieces, 1).unwrap();
            let full = signature(&path, stream, &spec);
            assert_close(&combined, &full, 2e-3, 1e-4);
            // Tree-combine agrees.
            let tree = multi_signature_combine(&spec, &sigs, pieces, 4).unwrap();
            assert_close(&tree, &full, 2e-3, 1e-4);
        });
    }

    #[test]
    fn combine_vjp_matches_finite_differences() {
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(31);
        let len = spec.sig_len();
        let count = 4;
        let sigs = rng.normal_vec(count * len, 0.4);
        let g = rng.normal_vec(len, 1.0);
        let grads = multi_signature_combine_vjp(&spec, &sigs, count, &g).unwrap();
        let h = 1e-2f32;
        for i in 0..sigs.len() {
            let mut sp = sigs.clone();
            sp[i] += h;
            let mut sm = sigs.clone();
            sm[i] -= h;
            let fp = multi_signature_combine(&spec, &sp, count, 1).unwrap();
            let fm = multi_signature_combine(&spec, &sm, count, 1).unwrap();
            let fd: f32 = fp
                .iter()
                .zip(&fm)
                .zip(&g)
                .map(|((&p, &m), &gv)| (p - m) / (2.0 * h) * gv)
                .sum();
            assert!(
                (fd - grads[i]).abs() < 3e-2 * (1.0 + fd.abs()),
                "grad[{i}]: fd={fd} vjp={}",
                grads[i]
            );
        }
    }

    #[test]
    fn pairwise_vjp_consistency() {
        // multi_signature_combine_vjp with count=2 equals
        // signature_combine_vjp.
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(9);
        let len = spec.sig_len();
        let s1 = rng.normal_vec(len, 0.5);
        let s2 = rng.normal_vec(len, 0.5);
        let g = rng.normal_vec(len, 1.0);
        let mut both = s1.clone();
        both.extend_from_slice(&s2);
        let multi = multi_signature_combine_vjp(&spec, &both, 2, &g).unwrap();
        let mut g1 = vec![0.0f32; len];
        let mut g2 = vec![0.0f32; len];
        signature_combine_vjp(&spec, &s1, &s2, &g, &mut g1, &mut g2);
        assert_close(&multi[..len], &g1, 1e-6, 1e-7);
        assert_close(&multi[len..], &g2, 1e-6, 1e-7);
    }

    #[test]
    fn single_signature_combine_is_identity() {
        let spec = SigSpec::new(2, 2).unwrap();
        let sigs = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        assert_eq!(multi_signature_combine(&spec, &sigs, 1, 1).unwrap(), sigs);
        let g = vec![0.5f32; 6];
        assert_eq!(multi_signature_combine_vjp(&spec, &sigs, 1, &g).unwrap(), g);
    }

    #[test]
    fn shape_errors() {
        let spec = SigSpec::new(2, 2).unwrap();
        assert!(multi_signature_combine(&spec, &[0.0; 5], 1, 1).is_err());
        assert!(multi_signature_combine(&spec, &[], 0, 1).is_err());
        assert!(multi_signature_combine_vjp(&spec, &[0.0; 6], 1, &[0.0; 2]).is_err());
    }
}
