//! Handwritten backward pass through the signature transform (§5.3,
//! App. C), exploiting **reversibility**:
//!
//! `Sig(x_1..x_{L-1}) = Sig(x_1..x_L) ⊠ Sig(x_L, x_{L-1}) = S_L ⊠ exp(-z_{L-1})`
//!
//! so the intermediate prefix signatures needed by the backward pass are
//! *recomputed in reverse order* from the final signature instead of being
//! stored — O(1) retained signatures instead of O(L) (App. C.1; the adjoint
//! method, exact here because the path is piecewise affine). Each reverse
//! step reuses the same fused multiply-exponentiate as the forward pass.
//!
//! ## Stream-parallel backward via the chunked Chen identity
//!
//! The paper (App. C.3) only parallelises the backward pass over the batch
//! dimension, because the reverse sweep itself is a serial recurrence. This
//! module additionally parallelises over the *stream*: split the increments
//! into per-thread chunks with signatures `M_c`, so that by Chen's identity
//!
//! `Sig = L_c ⊠ M_c ⊠ R_c`,  `L_c = M_0 ⊠ … ⊠ M_{c-1}`,  `R_c = M_{c+1} ⊠ … `
//!
//! Two serial O(chunks) sweeps produce every prefix `L_c` and suffix
//! product `T_c = M_c ⊠ R_c`; the cotangent of each `M_c` then follows from
//! two ⊠-VJPs (`out = L_c ⊠ T_c`, then `T_c = M_c ⊠ T_{c+1}`), and each
//! chunk runs the ordinary reversible reverse sweep over its own points —
//! **fully in parallel**. Total work is ≈1.5× the serial backward (each
//! increment pays one extra fused forward step inside its chunk), so at
//! `T` threads the wall-clock speedup approaches `T / 1.5`.
//!
//! The parallel path engages when [`SigConfig::threads`]` > 1` and the
//! effective stream has at least [`PARALLEL_BACKWARD_MIN_POINTS`] points;
//! shorter streams and `threads == 1` fall back to the serial sweep (the
//! chunk bookkeeping costs more than it saves on tiny inputs, and the
//! serial sweep is the bitwise-reference behaviour).

use super::SigConfig;
use crate::exec::{ExecPlan, ExecPlanner, WorkShape};
use crate::parallel::chunk_signatures;
use crate::substrate::pool::parallel_map_indexed;
use crate::ta::batch::{fused_mexp_batch, fused_mexp_vjp_batch, pack_lanes, BatchWorkspace};
use crate::ta::fused::{fused_mexp, fused_mexp_vjp};
use crate::ta::mul::{mul_assign, mul_into, mul_vjp};
use crate::ta::{Elem, SigSpec, Workspace};

/// Re-exported from the execution planner, which owns all strategy
/// constants (see [`crate::exec`]).
pub use crate::exec::PARALLEL_BACKWARD_MIN_POINTS;

/// Result of a signature VJP. Generic over the element precision with an
/// f32 default, matching the precision of the path / cotangent buffers.
#[derive(Clone, Debug)]
pub struct SigVjpResult<E: Elem = f32> {
    /// `∂L/∂path`, shape `(stream, d)` matching the input path buffer.
    pub grad_path: Vec<E>,
    /// `∂L/∂basepoint` if a basepoint was configured.
    pub grad_basepoint: Option<Vec<E>>,
    /// `∂L/∂initial` if an initial signature was configured.
    pub grad_initial: Option<Vec<E>>,
}

/// Core serial reverse sweep over an *effective* point sequence.
///
/// `final_sig` must be the forward output `initial ⊠ Sig(points)`. Returns
/// `(grad_points (E,d), grad_initial)`; `grad_initial` is the cotangent
/// remaining on the state after unwinding every increment.
fn reverse_sweep<'a, E: Elem>(
    spec: &SigSpec,
    n_points: usize,
    point: impl Fn(usize) -> &'a [E],
    final_sig: &[E],
    g: &[E],
    ws: &mut Workspace<E>,
) -> (Vec<E>, Vec<E>) {
    let d = spec.d();
    let mut grad_points = vec![E::ZERO; n_points * d];
    let mut s_cur = final_sig.to_vec();
    let mut g_state = g.to_vec();
    let mut z = vec![E::ZERO; d];
    let mut neg_z = vec![E::ZERO; d];
    let mut gz = vec![E::ZERO; d];
    let mut g_prev = spec.zeros_elem::<E>();
    for i in (1..n_points).rev() {
        let prev = point(i - 1);
        let cur = point(i);
        for c in 0..d {
            z[c] = cur[c] - prev[c];
            neg_z[c] = -z[c];
        }
        // Reversibility: recover S_{i-1} = S_i ⊠ exp(-z_i)  (eq. 18).
        fused_mexp(spec, &mut s_cur, &neg_z, ws);
        // VJP through S_i = S_{i-1} ⊠ exp(z_i).
        g_prev.fill(E::ZERO);
        gz.fill(E::ZERO);
        fused_mexp_vjp(spec, &s_cur, &z, &g_state, &mut g_prev, &mut gz, ws);
        std::mem::swap(&mut g_state, &mut g_prev);
        for c in 0..d {
            grad_points[i * d + c] += gz[c];
            grad_points[(i - 1) * d + c] -= gz[c];
        }
    }
    (grad_points, g_state)
}

/// Chunked stream-parallel reverse sweep (see the module docs).
///
/// Returns `(grad_points (n_points, d), grad_initial)`; `grad_initial` is
/// the cotangent on `initial`, and is left at zero when no initial
/// signature is configured (the caller discards it in that case).
fn parallel_reverse_sweep<'a, E, F>(
    spec: &SigSpec,
    n_points: usize,
    point: F,
    initial: Option<&[E]>,
    g: &[E],
    threads: usize,
) -> (Vec<E>, Vec<E>)
where
    E: Elem,
    F: Fn(usize) -> &'a [E] + Sync,
{
    let d = spec.d();
    let len = spec.sig_len();
    // Stage 1 (parallel): per-chunk signatures M_c, identical to the
    // forward reduction's first stage.
    let (ranges, chunk_sigs) = chunk_signatures(spec, n_points, &point, threads);
    let chunks = ranges.len();

    // Stage 2 (serial, O(chunks)): prefix states L_c = initial ⊠ M_0 ⊠ …
    // ⊠ M_{c-1} entering each chunk…
    let mut prefixes = vec![E::ZERO; chunks * len];
    {
        let mut acc = match initial {
            Some(init) => init.to_vec(),
            None => spec.zeros_elem::<E>(),
        };
        for c in 0..chunks {
            prefixes[c * len..(c + 1) * len].copy_from_slice(&acc);
            if c + 1 < chunks {
                mul_assign(spec, &mut acc, &chunk_sigs[c]);
            }
        }
    }
    // …and suffix products T_c = M_c ⊠ … ⊠ M_{chunks-1} (right to left),
    // so Sig-with-initial = L_c ⊠ T_c for every c.
    let mut suffixes = vec![E::ZERO; chunks * len];
    suffixes[(chunks - 1) * len..].copy_from_slice(&chunk_sigs[chunks - 1]);
    for c in (0..chunks - 1).rev() {
        let (lo, hi) = suffixes.split_at_mut((c + 1) * len);
        mul_into(spec, &chunk_sigs[c], &hi[..len], &mut lo[c * len..(c + 1) * len]);
    }

    // Cotangent left on the initial state: out = initial ⊠ T_0. Skipped
    // when no initial is configured — the caller discards it there, and
    // this is a full ⊠-VJP.
    let mut grad_initial = spec.zeros_elem::<E>();
    if initial.is_some() {
        let init = &prefixes[..len]; // == initial
        let mut g_t0 = spec.zeros_elem::<E>();
        mul_vjp(spec, init, &suffixes[..len], g, &mut grad_initial, &mut g_t0);
    }

    // Stage 3 (parallel): derive each chunk's cotangent with two ⊠-VJPs,
    // then run the ordinary reversible reverse sweep inside the chunk.
    let per_chunk = parallel_map_indexed(chunks, threads, |c| {
        let (s, e) = ranges[c];
        // out = L_c ⊠ T_c  ⇒  cotangent on the suffix from chunk c.
        let mut g_suffix = spec.zeros_elem::<E>();
        let mut discard = spec.zeros_elem::<E>();
        mul_vjp(
            spec,
            &prefixes[c * len..(c + 1) * len],
            &suffixes[c * len..(c + 1) * len],
            g,
            &mut discard,
            &mut g_suffix,
        );
        // T_c = M_c ⊠ T_{c+1}  ⇒  cotangent on this chunk's signature.
        let g_chunk = if c + 1 == chunks {
            g_suffix
        } else {
            let mut g_chunk = spec.zeros_elem::<E>();
            discard.fill(E::ZERO);
            mul_vjp(
                spec,
                &chunk_sigs[c],
                &suffixes[(c + 1) * len..(c + 2) * len],
                &g_suffix,
                &mut g_chunk,
                &mut discard,
            );
            g_chunk
        };
        // M_c is an identity-initialised signature of points s..=e, so the
        // serial reverse sweep applies to the chunk unchanged; the residual
        // state cotangent is ∂/∂identity and is discarded.
        let mut ws = Workspace::<E>::new(spec);
        let (grads, _g_identity) =
            reverse_sweep(spec, e - s + 1, |i| point(s + i), &chunk_sigs[c], &g_chunk, &mut ws);
        grads
    });

    // Scatter-accumulate: adjacent chunks share their boundary point, so
    // contributions add there.
    let mut grad_points = vec![E::ZERO; n_points * d];
    for (c, grads) in per_chunk.into_iter().enumerate() {
        let (s, _) = ranges[c];
        for (k, &gv) in grads.iter().enumerate() {
            grad_points[s * d + k] += gv;
        }
    }
    (grad_points, grad_initial)
}

/// VJP of [`super::signature`]: given `g = ∂L/∂Sig(path)`, returns
/// `∂L/∂path` (same shape as `path`). Serial; see [`signature_vjp_with`]
/// for the stream-parallel and configurable version.
pub fn signature_vjp<E: Elem>(path: &[E], stream: usize, spec: &SigSpec, g: &[E]) -> Vec<E> {
    signature_vjp_with(path, stream, spec, &SigConfig::serial(), g)
        .expect("valid path")
        .grad_path
}

/// VJP of [`super::signature_with`] honouring basepoint / initial /
/// inverse / threads.
///
/// With `threads == 1` (or a short stream) this recomputes the forward
/// pass (one O(L) fused sweep) and unwinds it serially via reversibility;
/// with `threads > 1` and at least [`PARALLEL_BACKWARD_MIN_POINTS`]
/// effective points it runs the chunked Chen-identity backward described
/// in the module docs, parallel over the stream.
pub fn signature_vjp_with<E: Elem>(
    path: &[E],
    stream: usize,
    spec: &SigSpec,
    cfg: &SigConfig,
    g: &[E],
) -> anyhow::Result<SigVjpResult<E>> {
    let d = spec.d();
    anyhow::ensure!(
        g.len() == spec.sig_len(),
        "cotangent has {} values, expected sig_len {}",
        g.len(),
        spec.sig_len()
    );
    // Shared with the forward pass; the parallel branch below never calls
    // signature_with, so shapes must be validated here.
    let eff_len = super::forward::check_path_with(path, stream, spec, cfg)?;

    // Config options are declared in f32 (the wire format); lift them into
    // E once up front — the identity for E = f32.
    let basepoint: Option<Vec<E>> =
        cfg.basepoint.as_ref().map(|bp| bp.iter().map(|&v| E::from_f32(v)).collect());
    let initial: Option<Vec<E>> =
        cfg.initial.as_ref().map(|init| init.iter().map(|&v| E::from_f32(v)).collect());
    let point = |i: usize| -> &[E] {
        let i = if cfg.inverse { eff_len - 1 - i } else { i };
        match &basepoint {
            Some(bp) => {
                if i == 0 {
                    bp.as_slice()
                } else {
                    &path[(i - 1) * d..i * d]
                }
            }
            None => &path[i * d..(i + 1) * d],
        }
    };

    // Strategy selection lives in the execution planner (crate::exec).
    let plan = ExecPlanner::new(cfg.threads).plan_backward(&WorkShape {
        batch: 1,
        points: eff_len,
        d,
        depth: spec.depth(),
        dtype: E::PRECISION,
    });
    let (grad_eff, g_initial) = match plan {
        ExecPlan::StreamParallel { threads } => {
            parallel_reverse_sweep(spec, eff_len, point, initial.as_deref(), g, threads)
        }
        // LaneFused never arises for batch = 1; run the reference sweep.
        ExecPlan::Scalar | ExecPlan::LaneFused { .. } => {
            // Serial: recompute the forward (one O(L) fused sweep) to
            // obtain the final signature, then unwind it via
            // reversibility.
            let forward_cfg = SigConfig { threads: 1, ..cfg.clone() };
            let final_sig = super::forward::signature_with(path, stream, spec, &forward_cfg)?;
            let mut ws = Workspace::<E>::new(spec);
            reverse_sweep(spec, eff_len, point, &final_sig, g, &mut ws)
        }
    };

    // Undo the effective-point mapping: reversal then basepoint.
    let unreversed: Vec<E> = if cfg.inverse {
        let mut v = vec![E::ZERO; eff_len * d];
        for i in 0..eff_len {
            v[(eff_len - 1 - i) * d..(eff_len - i) * d]
                .copy_from_slice(&grad_eff[i * d..(i + 1) * d]);
        }
        v
    } else {
        grad_eff
    };
    let (grad_basepoint, grad_path) = match &cfg.basepoint {
        Some(_) => (Some(unreversed[..d].to_vec()), unreversed[d..].to_vec()),
        None => (None, unreversed),
    };
    let grad_initial = cfg.initial.as_ref().map(|_| g_initial);
    Ok(SigVjpResult { grad_path, grad_basepoint, grad_initial })
}

/// VJP of [`super::signature_stream`]: `g` has shape
/// `(stream - 1, sig_len)` — a cotangent for every prefix signature.
///
/// Cotangents are *accumulated* onto the running state as the reverse sweep
/// passes each prefix, so the cost stays one fused VJP per increment. This
/// entry point is serial over the stream: every increment's cotangent
/// depends on all later prefix cotangents, so the chunked-Chen
/// factorisation above does not apply to the per-prefix output.
pub fn signature_stream_vjp(
    path: &[f32],
    stream: usize,
    spec: &SigSpec,
    g: &[f32],
) -> anyhow::Result<Vec<f32>> {
    let d = spec.d();
    let len = spec.sig_len();
    anyhow::ensure!(stream >= 2, "need at least two points");
    anyhow::ensure!(path.len() == stream * d, "path buffer wrong length");
    anyhow::ensure!(
        g.len() == (stream - 1) * len,
        "cotangent has {} values, expected (stream-1) * sig_len = {}",
        g.len(),
        (stream - 1) * len
    );
    let final_sig = super::forward::signature(path, stream, spec);
    let mut ws = Workspace::new(spec);
    let mut grad_path = vec![0.0f32; stream * d];
    let mut s_cur = final_sig;
    let mut g_state = vec![0.0f32; len];
    let mut z = vec![0.0f32; d];
    let mut neg_z = vec![0.0f32; d];
    let mut gz = vec![0.0f32; d];
    let mut g_prev = spec.zeros();
    for i in (1..stream).rev() {
        // Prefix signature S_i (ending at point i) has cotangent g[i-1].
        for (acc, &gv) in g_state.iter_mut().zip(&g[(i - 1) * len..i * len]) {
            *acc += gv;
        }
        for c in 0..d {
            z[c] = path[i * d + c] - path[(i - 1) * d + c];
            neg_z[c] = -z[c];
        }
        fused_mexp(spec, &mut s_cur, &neg_z, &mut ws);
        g_prev.fill(0.0);
        gz.fill(0.0);
        fused_mexp_vjp(spec, &s_cur, &z, &g_state, &mut g_prev, &mut gz, &mut ws);
        std::mem::swap(&mut g_state, &mut g_prev);
        for c in 0..d {
            grad_path[i * d + c] += gz[c];
            grad_path[(i - 1) * d + c] -= gz[c];
        }
    }
    Ok(grad_path)
}

/// Batched VJP over a `(batch, stream, d)` buffer (App. C.3).
///
/// Strategy selection goes through [`crate::exec::ExecPlanner`]
/// ([`crate::exec::ExecPlanner::plan_backward`]); in order of preference:
/// surplus threads (`threads > batch`) run per-path dispatch with the
/// chunked Chen-identity stream-parallel backward inside each sample;
/// `batch >= 2` runs the **lane-fused** batched reverse sweep at **any**
/// `d` — blocks of up to the shape's lane width
/// ([`crate::exec::lane_width`], at most
/// [`super::forward::MAX_LANE_WIDTH`]) samples recompute
/// prefixes and unwind together through the interleaved batch kernels,
/// bitwise identical to the serial per-path VJP (the scalar dispatcher's
/// monomorphised bodies cover `d ≤` [`crate::exec::LANE_VJP_MAX_D`] and
/// the runtime-`d` `fused_mexp_vjp_dyn` covers the rest, all in the same
/// op order); otherwise per-path serial sweeps, parallel over the batch.
pub fn signature_batch_vjp<E: Elem>(
    paths: &[E],
    batch: usize,
    stream: usize,
    spec: &SigSpec,
    g: &[E],
    threads: usize,
) -> anyhow::Result<Vec<E>> {
    let plan = ExecPlanner::new(threads).plan_backward(&WorkShape {
        batch,
        points: stream,
        d: spec.d(),
        depth: spec.depth(),
        dtype: E::PRECISION,
    });
    signature_batch_vjp_planned(paths, batch, stream, spec, g, threads, plan)
}

/// Execute a batched VJP under an explicit [`ExecPlan`] (see
/// [`signature_batch_vjp`] for the planner-selected entry point). The
/// batched logsignature VJP ([`crate::logsignature::batch`]) executes the
/// same plans through this shared executor, handing it the signature
/// cotangents its O(sig_len) per-lane epilogue produced.
pub fn signature_batch_vjp_planned<E: Elem>(
    paths: &[E],
    batch: usize,
    stream: usize,
    spec: &SigSpec,
    g: &[E],
    threads: usize,
    plan: ExecPlan,
) -> anyhow::Result<Vec<E>> {
    let len = spec.sig_len();
    let plen = stream * spec.d();
    anyhow::ensure!(batch >= 1, "need at least one sample");
    anyhow::ensure!(stream >= 2, "need at least two points per path, got {stream}");
    anyhow::ensure!(paths.len() == batch * plen, "batch buffer wrong length");
    anyhow::ensure!(
        g.len() == batch * len,
        "cotangent has {} values, expected batch * sig_len = {}",
        g.len(),
        batch * len
    );
    let threads = threads.max(1);
    if let ExecPlan::LaneFused { block } = plan {
        if batch >= 2 {
            let block = block.clamp(1, super::forward::MAX_LANE_WIDTH);
            let n_blocks = batch.div_ceil(block);
            let blocks = parallel_map_indexed(n_blocks, threads, |bi| {
                let l0 = bi * block;
                let lanes = block.min(batch - l0);
                lane_reverse_sweep(spec, paths, stream, l0, lanes, g)
            });
            let mut out = vec![E::ZERO; batch * plen];
            for (bi, rows) in blocks.into_iter().enumerate() {
                let o = bi * block * plen;
                out[o..o + rows.len()].copy_from_slice(&rows);
            }
            return Ok(out);
        }
    }
    // Per-path dispatch: stream parallelism inside each sample when the
    // plan grants it, the serial reference sweep otherwise.
    let stream_threads = match plan {
        ExecPlan::StreamParallel { threads } => threads,
        _ => 1,
    };
    let cfg = SigConfig { threads: stream_threads, ..SigConfig::serial() };
    let grads = parallel_map_indexed(batch, threads, |b| {
        signature_vjp_with(
            &paths[b * plen..(b + 1) * plen],
            stream,
            spec,
            &cfg,
            &g[b * len..(b + 1) * len],
        )
        .map(|r| r.grad_path)
    });
    let mut out = vec![E::ZERO; batch * plen];
    for (b, gp) in grads.into_iter().enumerate() {
        out[b * plen..(b + 1) * plen].copy_from_slice(&gp?);
    }
    Ok(out)
}

/// Lane-fused batched reverse sweep over one block of `lanes` samples
/// starting at lane `l0`: one interleaved forward pass to the final
/// signatures, then the reversibility unwind with the batched fused VJP —
/// each lane performs exactly the serial [`reverse_sweep`]'s operations,
/// so the result is bitwise identical to [`signature_vjp`] per sample.
fn lane_reverse_sweep<E: Elem>(
    spec: &SigSpec,
    paths: &[E],
    stream: usize,
    l0: usize,
    lanes: usize,
    g: &[E],
) -> Vec<E> {
    let d = spec.d();
    let len = spec.sig_len();
    let plen = stream * d;
    let path_at =
        |l: usize, i: usize| &paths[(l0 + l) * plen + i * d..(l0 + l) * plen + (i + 1) * d];
    let mut ws = BatchWorkspace::<E>::new(spec, lanes);
    let mut state = vec![E::ZERO; len * lanes];
    let mut z = vec![E::ZERO; d * lanes];
    let mut neg_z = vec![E::ZERO; d * lanes];
    // Forward to the final signatures (lane-interleaved).
    for i in 1..stream {
        for l in 0..lanes {
            let prev = path_at(l, i - 1);
            let cur = path_at(l, i);
            for c in 0..d {
                z[c * lanes + l] = cur[c] - prev[c];
            }
        }
        fused_mexp_batch(spec, &mut state, &z, &mut ws);
    }
    // Unwind via reversibility.
    let mut g_state = vec![E::ZERO; len * lanes];
    pack_lanes(len, lanes, |l| &g[(l0 + l) * len..(l0 + l + 1) * len], &mut g_state);
    let mut g_prev = vec![E::ZERO; len * lanes];
    let mut gz = vec![E::ZERO; d * lanes];
    let mut grads = vec![E::ZERO; lanes * plen];
    for i in (1..stream).rev() {
        for l in 0..lanes {
            let prev = path_at(l, i - 1);
            let cur = path_at(l, i);
            for c in 0..d {
                let zc = cur[c] - prev[c];
                z[c * lanes + l] = zc;
                neg_z[c * lanes + l] = -zc;
            }
        }
        // Reversibility: recover S_{i-1} = S_i ⊠ exp(-z_i)  (eq. 18).
        fused_mexp_batch(spec, &mut state, &neg_z, &mut ws);
        g_prev.fill(E::ZERO);
        gz.fill(E::ZERO);
        fused_mexp_vjp_batch(spec, &state, &z, &g_state, &mut g_prev, &mut gz, &mut ws);
        std::mem::swap(&mut g_state, &mut g_prev);
        for l in 0..lanes {
            for c in 0..d {
                let gv = gz[c * lanes + l];
                grads[l * plen + i * d + c] += gv;
                grads[l * plen + (i - 1) * d + c] -= gv;
            }
        }
    }
    grads
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::signature::forward::{signature, signature_stream, signature_with, LANE_BLOCK};
    use crate::substrate::propcheck::{assert_close, property};
    use crate::substrate::rng::Rng;

    fn random_path(rng: &mut Rng, stream: usize, d: usize) -> Vec<f32> {
        let mut p = vec![0.0f32; stream * d];
        for i in 1..stream {
            for c in 0..d {
                p[i * d + c] = p[(i - 1) * d + c] + rng.normal_f32() * 0.3;
            }
        }
        p
    }

    fn fd_grad<F>(path: &[f32], g: &[f32], f: F, h: f32) -> Vec<f32>
    where
        F: Fn(&[f32]) -> Vec<f32>,
    {
        let mut grad = vec![0.0f32; path.len()];
        for i in 0..path.len() {
            let mut pp = path.to_vec();
            pp[i] += h;
            let mut pm = path.to_vec();
            pm[i] -= h;
            grad[i] = f(&pp)
                .iter()
                .zip(f(&pm).iter())
                .zip(g)
                .map(|((&a, &b), &gv)| (a - b) / (2.0 * h) * gv)
                .sum();
        }
        grad
    }

    fn check_grads(got: &[f32], fd: &[f32], tol: f32) {
        for i in 0..got.len() {
            assert!(
                (got[i] - fd[i]).abs() <= tol * (1.0 + fd[i].abs()),
                "grad[{i}]: vjp={} fd={}",
                got[i],
                fd[i]
            );
        }
    }

    #[test]
    fn vjp_matches_finite_differences() {
        property("signature vjp fd", 6, |gen| {
            let d = gen.usize_in(1, 3);
            let n = gen.usize_in(1, 4);
            let stream = gen.usize_in(2, 8);
            gen.label(format!("d={d} n={n} stream={stream}"));
            let spec = SigSpec::new(d, n).unwrap();
            let path = random_path(gen.rng(), stream, d);
            let g = gen.normal_vec(spec.sig_len(), 1.0);
            let grad = signature_vjp(&path, stream, &spec, &g);
            let fd = fd_grad(&path, &g, |p| signature(p, stream, &spec), 1e-2);
            check_grads(&grad, &fd, 4e-2);
        });
    }

    #[test]
    fn stream_vjp_matches_finite_differences() {
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(55);
        let stream = 6;
        let path = random_path(&mut rng, stream, 2);
        let g = rng.normal_vec((stream - 1) * spec.sig_len(), 1.0);
        let grad = signature_stream_vjp(&path, stream, &spec, &g).unwrap();
        let fd = fd_grad(&path, &g, |p| signature_stream(p, stream, &spec), 1e-2);
        check_grads(&grad, &fd, 4e-2);
    }

    #[test]
    fn vjp_with_basepoint_and_initial() {
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(10);
        let stream = 5;
        let path = random_path(&mut rng, stream, 2);
        let bp = vec![0.1f32, -0.2];
        let init_path = random_path(&mut rng, 4, 2);
        let init = signature(&init_path, 4, &spec);
        let cfg = SigConfig {
            basepoint: Some(bp.clone()),
            initial: Some(init.clone()),
            ..SigConfig::serial()
        };
        let g = rng.normal_vec(spec.sig_len(), 1.0);
        let res = signature_vjp_with(&path, stream, &spec, &cfg, &g).unwrap();
        assert_eq!(res.grad_path.len(), path.len());
        let gb = res.grad_basepoint.unwrap();
        assert_eq!(gb.len(), 2);
        let gi = res.grad_initial.unwrap();
        assert_eq!(gi.len(), spec.sig_len());

        // FD check on the path.
        let f = |p: &[f32]| signature_with(p, stream, &spec, &cfg).unwrap();
        let fd = fd_grad(&path, &g, f, 1e-2);
        check_grads(&res.grad_path, &fd, 5e-2);
        // FD check on the basepoint.
        let fb = |b: &[f32]| {
            let c = SigConfig { basepoint: Some(b.to_vec()), initial: Some(init.clone()), ..SigConfig::serial() };
            signature_with(&path, stream, &spec, &c).unwrap()
        };
        let fd_b = fd_grad(&bp, &g, fb, 1e-2);
        check_grads(&gb, &fd_b, 5e-2);
        // FD check on the initial signature.
        let fi = |iv: &[f32]| {
            let c = SigConfig { basepoint: Some(bp.clone()), initial: Some(iv.to_vec()), ..SigConfig::serial() };
            signature_with(&path, stream, &spec, &c).unwrap()
        };
        let fd_i = fd_grad(&init, &g, fi, 1e-2);
        check_grads(&gi, &fd_i, 5e-2);
    }

    #[test]
    fn vjp_inverse_mode() {
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(12);
        let stream = 5;
        let path = random_path(&mut rng, stream, 2);
        let cfg = SigConfig { inverse: true, ..SigConfig::serial() };
        let g = rng.normal_vec(spec.sig_len(), 1.0);
        let res = signature_vjp_with(&path, stream, &spec, &cfg, &g).unwrap();
        let f = |p: &[f32]| signature_with(p, stream, &spec, &cfg).unwrap();
        let fd = fd_grad(&path, &g, f, 1e-2);
        check_grads(&res.grad_path, &fd, 5e-2);
    }

    #[test]
    fn gradient_of_first_level_is_endpoint_difference() {
        // d/dx of Sig level 1 = x_L - x_1: cotangent e_c on level 1 puts
        // +1 on x_L[c] and -1 on x_1[c].
        let spec = SigSpec::new(3, 2).unwrap();
        let mut rng = Rng::new(2);
        let stream = 7;
        let path = random_path(&mut rng, stream, 3);
        let mut g = vec![0.0f32; spec.sig_len()];
        g[1] = 1.0; // level-1 channel 1
        let grad = signature_vjp(&path, stream, &spec, &g);
        for i in 0..stream {
            for c in 0..3 {
                let expect = if i == 0 && c == 1 {
                    -1.0
                } else if i == stream - 1 && c == 1 {
                    1.0
                } else {
                    0.0
                };
                assert!(
                    (grad[i * 3 + c] - expect).abs() < 1e-4,
                    "grad[{i},{c}] = {}",
                    grad[i * 3 + c]
                );
            }
        }
    }

    #[test]
    fn batch_vjp_matches_per_sample() {
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(77);
        let (b, stream) = (4, 6);
        let mut paths = vec![0.0f32; b * stream * 2];
        for i in 0..b {
            let p = random_path(&mut rng, stream, 2);
            paths[i * stream * 2..(i + 1) * stream * 2].copy_from_slice(&p);
        }
        let g = rng.normal_vec(b * spec.sig_len(), 1.0);
        let out = signature_batch_vjp(&paths, b, stream, &spec, &g, 3).unwrap();
        for i in 0..b {
            let single = signature_vjp(
                &paths[i * stream * 2..(i + 1) * stream * 2],
                stream,
                &spec,
                &g[i * spec.sig_len()..(i + 1) * spec.sig_len()],
            );
            for (a, e) in out[i * stream * 2..(i + 1) * stream * 2].iter().zip(&single) {
                assert_eq!(a, e);
            }
        }
    }

    #[test]
    fn parallel_backward_matches_serial() {
        // Acceptance: the chunked Chen backward reproduces the serial
        // reverse sweep within the parallel_matches_serial bounds.
        property("parallel backward == serial", 12, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let stream = g.usize_in(PARALLEL_BACKWARD_MIN_POINTS + 8, 220);
            let threads = g.usize_in(2, 8);
            g.label(format!("d={d} n={n} stream={stream} t={threads}"));
            let spec = SigSpec::new(d, n).unwrap();
            let path = random_path(g.rng(), stream, d);
            let cot = g.normal_vec(spec.sig_len(), 1.0);
            let serial = signature_vjp(&path, stream, &spec, &cot);
            let cfg = SigConfig::parallel(threads);
            let par = signature_vjp_with(&path, stream, &spec, &cfg, &cot).unwrap().grad_path;
            assert_close(&par, &serial, 2e-3, 1e-4);
        });
    }

    #[test]
    fn parallel_backward_with_basepoint_initial_and_inverse() {
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(91);
        let stream = 64;
        let path = random_path(&mut rng, stream, 2);
        let init = signature(&random_path(&mut rng, 6, 2), 6, &spec);
        let cot = rng.normal_vec(spec.sig_len(), 1.0);
        for inverse in [false, true] {
            let serial_cfg = SigConfig {
                basepoint: Some(vec![0.2, -0.4]),
                initial: Some(init.clone()),
                inverse,
                ..SigConfig::serial()
            };
            let par_cfg = SigConfig { threads: 5, ..serial_cfg.clone() };
            let serial = signature_vjp_with(&path, stream, &spec, &serial_cfg, &cot).unwrap();
            let par = signature_vjp_with(&path, stream, &spec, &par_cfg, &cot).unwrap();
            assert_close(&par.grad_path, &serial.grad_path, 2e-3, 1e-4);
            assert_close(
                &par.grad_basepoint.unwrap(),
                &serial.grad_basepoint.unwrap(),
                2e-3,
                1e-4,
            );
            assert_close(
                &par.grad_initial.unwrap(),
                &serial.grad_initial.unwrap(),
                2e-3,
                1e-4,
            );
        }
    }

    #[test]
    fn short_streams_fall_back_to_serial_bitwise() {
        // Below the threshold the parallel config must take the serial
        // path and produce bit-identical gradients.
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(14);
        let stream = PARALLEL_BACKWARD_MIN_POINTS - 2;
        let path = random_path(&mut rng, stream, 2);
        let cot = rng.normal_vec(spec.sig_len(), 1.0);
        let serial = signature_vjp(&path, stream, &spec, &cot);
        let par = signature_vjp_with(&path, stream, &spec, &SigConfig::parallel(8), &cot)
            .unwrap()
            .grad_path;
        assert_eq!(par, serial);
    }

    #[test]
    fn batch_vjp_spreads_threads_over_streams() {
        // batch 2 with 8 threads => 4-way stream parallelism per sample.
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(21);
        let (b, stream) = (2, 80);
        let mut paths = vec![0.0f32; b * stream * 2];
        for i in 0..b {
            let p = random_path(&mut rng, stream, 2);
            paths[i * stream * 2..(i + 1) * stream * 2].copy_from_slice(&p);
        }
        let g = rng.normal_vec(b * spec.sig_len(), 1.0);
        let out = signature_batch_vjp(&paths, b, stream, &spec, &g, 8).unwrap();
        for i in 0..b {
            let single = signature_vjp(
                &paths[i * stream * 2..(i + 1) * stream * 2],
                stream,
                &spec,
                &g[i * spec.sig_len()..(i + 1) * spec.sig_len()],
            );
            assert_close(&out[i * stream * 2..(i + 1) * stream * 2], &single, 2e-3, 1e-4);
        }
    }

    #[test]
    fn vjp_entry_points_error_on_bad_shapes() {
        let spec = SigSpec::new(2, 3).unwrap();
        let len = spec.sig_len();
        let path = vec![0.0f32; 10 * 2];
        let cfg = SigConfig::serial();
        let good_g = vec![0.0f32; len];
        let short_g = vec![0.0f32; len - 1];
        // Wrong cotangent length.
        assert!(signature_vjp_with(&path, 10, &spec, &cfg, &short_g).is_err());
        // Wrong path buffer length.
        assert!(signature_vjp_with(&path, 11, &spec, &cfg, &good_g).is_err());
        // Bad basepoint / initial shapes.
        let bad_bp = SigConfig { basepoint: Some(vec![0.0; 3]), ..SigConfig::serial() };
        assert!(signature_vjp_with(&path, 10, &spec, &bad_bp, &good_g).is_err());
        let bad_init = SigConfig { initial: Some(vec![0.0; 2]), ..SigConfig::serial() };
        assert!(signature_vjp_with(&path, 10, &spec, &bad_init, &good_g).is_err());
        // Stream VJP shape checks.
        let short_stream_g = vec![0.0f32; 9 * len - 1];
        assert!(signature_stream_vjp(&path, 10, &spec, &short_stream_g).is_err());
        assert!(signature_stream_vjp(&path, 1, &spec, &[]).is_err());
        // Batch VJP shape checks.
        let two_g = vec![0.0f32; 2 * len];
        assert!(signature_batch_vjp(&path, 1, 10, &spec, &short_g, 2).is_err());
        assert!(signature_batch_vjp(&path, 2, 10, &spec, &two_g, 2).is_err());
        assert!(signature_batch_vjp::<f32>(&[], 0, 10, &spec, &[], 2).is_err());
    }

    #[test]
    fn batch_vjp_lane_engine_is_bitwise_per_sample() {
        // Multi-block lane dispatch (LANE_BLOCK + 3 samples ⇒ one full and
        // one ragged block) must reproduce the serial per-path VJP
        // bit-for-bit — the batched kernels perform each lane's ops in the
        // scalar order.
        let spec = SigSpec::new(3, 3).unwrap();
        let mut rng = Rng::new(88);
        let (b, stream) = (LANE_BLOCK + 3, 9);
        let plen = stream * 3;
        let mut paths = vec![0.0f32; b * plen];
        for i in 0..b {
            let p = random_path(&mut rng, stream, 3);
            paths[i * plen..(i + 1) * plen].copy_from_slice(&p);
        }
        let g = rng.normal_vec(b * spec.sig_len(), 1.0);
        let out = signature_batch_vjp(&paths, b, stream, &spec, &g, 4).unwrap();
        for i in 0..b {
            let single = signature_vjp(
                &paths[i * plen..(i + 1) * plen],
                stream,
                &spec,
                &g[i * spec.sig_len()..(i + 1) * spec.sig_len()],
            );
            assert_eq!(&out[i * plen..(i + 1) * plen], single.as_slice(), "sample {i}");
        }
    }

    #[test]
    fn batch_vjp_lane_engine_is_bitwise_beyond_the_mono_window() {
        // The issue's acceptance criterion: at d ∈ {9, 12, 20} the planner
        // now hands the batched backward a LaneFused plan, and the lane
        // engine must stay bitwise identical to scalar dispatch (which
        // runs fused_mexp_vjp_dyn at these dimensions) — in both
        // precisions. LANE_BLOCK + 1 samples force a ragged tail block.
        use crate::exec::ExecPlan;
        for (d, depth, stream) in [(9usize, 3usize, 5usize), (12, 3, 4), (20, 2, 5)] {
            let spec = SigSpec::new(d, depth).unwrap();
            let b = LANE_BLOCK + 1;
            let plen = stream * d;
            let mut rng = Rng::new(300 + d as u64);
            let mut paths = vec![0.0f32; b * plen];
            for i in 0..b {
                let p = random_path(&mut rng, stream, d);
                paths[i * plen..(i + 1) * plen].copy_from_slice(&p);
            }
            let g = rng.normal_vec(b * spec.sig_len(), 1.0);
            // The planner must actually choose LaneFused here (threads ≤
            // batch, no surplus): this is the plan the batch entry executes.
            let plan = ExecPlanner::new(4).plan_backward(&WorkShape {
                batch: b,
                points: stream,
                d,
                depth,
                dtype: crate::ta::Precision::F32,
            });
            assert!(matches!(plan, ExecPlan::LaneFused { .. }), "d={d}: expected LaneFused, got {plan:?}");
            // f32: lane engine vs per-sample scalar dispatch, bitwise.
            let out = signature_batch_vjp(&paths, b, stream, &spec, &g, 4).unwrap();
            for i in 0..b {
                let single = signature_vjp(
                    &paths[i * plen..(i + 1) * plen],
                    stream,
                    &spec,
                    &g[i * spec.sig_len()..(i + 1) * spec.sig_len()],
                );
                assert_eq!(&out[i * plen..(i + 1) * plen], single.as_slice(), "f32 d={d} sample {i}");
            }
            // f64: same property through the widened precision axis.
            let paths64: Vec<f64> = paths.iter().map(|&v| v as f64).collect();
            let g64: Vec<f64> = g.iter().map(|&v| v as f64).collect();
            let out64 = signature_batch_vjp(&paths64, b, stream, &spec, &g64, 4).unwrap();
            for i in 0..b {
                let single = signature_vjp(
                    &paths64[i * plen..(i + 1) * plen],
                    stream,
                    &spec,
                    &g64[i * spec.sig_len()..(i + 1) * spec.sig_len()],
                );
                assert_eq!(&out64[i * plen..(i + 1) * plen], single.as_slice(), "f64 d={d} sample {i}");
            }
        }
    }

    #[test]
    fn batch_vjp_short_stream_is_an_error() {
        // Regression companion to the forward fix: stream < 2 must be a
        // clean Err from the batched backward too, not a worker panic.
        let spec = SigSpec::new(2, 3).unwrap();
        let g = vec![0.0f32; 2 * spec.sig_len()];
        assert!(signature_batch_vjp(&[0.0; 4], 2, 1, &spec, &g, 2).is_err());
    }

    #[test]
    fn reversibility_reconstruction_is_accurate() {
        // The reverse sweep must recover early prefix signatures to high
        // accuracy even over longer streams (App. C.1: solved exactly, no
        // ODE-style reconstruction error; only f32 roundoff).
        let spec = SigSpec::new(3, 4).unwrap();
        let mut rng = Rng::new(5);
        let stream = 128;
        let path = random_path(&mut rng, stream, 3);
        // Forward final.
        let final_sig = signature(&path, stream, &spec);
        // Unwind all the way back: should recover the identity.
        let mut ws = Workspace::new(&spec);
        let mut s = final_sig;
        let mut neg_z = vec![0.0f32; 3];
        for i in (1..stream).rev() {
            for c in 0..3 {
                neg_z[c] = path[(i - 1) * 3 + c] - path[i * 3 + c];
            }
            fused_mexp(&spec, &mut s, &neg_z, &mut ws);
        }
        for (idx, &v) in s.iter().enumerate() {
            assert!(v.abs() < 2e-3, "residual {v} at {idx}");
        }
    }
}
