//! Precomputed per-(d, N, basis) data for logsignature projections.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::ta::{Elem, SigSpec};
use crate::words::{bracket_expansion, lyndon_words, witt_dimension, word_index};

/// Which representation of the logsignature to produce (§4.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LogSigBasis {
    /// The raw `log(Sig)` tensor in the word basis of the ambient tensor
    /// algebra (dimension `sig_len`).
    Expanded,
    /// Coefficients with respect to the Lyndon bracket basis `φ(ℓ)` —
    /// the classical choice, what `iisignature` produces. Requires a
    /// triangular solve with precomputed bracket expansions.
    Lyndon,
    /// The paper's new basis (App. A.2.3): `z = ψ(log Sig)`, i.e. the log
    /// tensor's coefficients at Lyndon-word positions. A pure gather.
    Words,
}

/// One Lyndon word's static data inside a plan.
#[derive(Clone, Debug)]
struct LyndonEntry {
    /// Level (= word length), 1-based.
    level: usize,
    /// Flat index within that level's tensor.
    index: usize,
    /// For the Lyndon basis: `φ(ℓ)` expanded over flat word indices of the
    /// same level, sorted ascending. Empty for other bases.
    expansion: Vec<(usize, f32)>,
}

/// Precomputed logsignature projection (Signatory's `LogSignature` class
/// analogue). Construction is `O(#Lyndon-words)` for `Words` and
/// substantially more for `Lyndon` (bracket expansions) — amortised across
/// every subsequent call, as the paper's precomputation strategies
/// recommend (§5).
pub struct LogSigPlan {
    spec: SigSpec,
    basis: LogSigBasis,
    entries: Vec<LyndonEntry>,
    dim: usize,
}

impl LogSigPlan {
    pub fn new(spec: &SigSpec, basis: LogSigBasis) -> anyhow::Result<LogSigPlan> {
        let d = spec.d();
        let n = spec.depth();
        let words = lyndon_words(d, n);
        let mut entries = Vec::with_capacity(words.len());
        for w in &words {
            let level = w.len();
            let index = word_index(w, d);
            let expansion = match basis {
                LogSigBasis::Lyndon => {
                    let poly = bracket_expansion(w);
                    let mut v: Vec<(usize, f32)> =
                        poly.iter().map(|(word, &c)| (word_index(word, d), c)).collect();
                    v.sort_unstable_by_key(|&(i, _)| i);
                    v
                }
                _ => Vec::new(),
            };
            entries.push(LyndonEntry { level, index, expansion });
        }
        // Order entries by (level, lex) — word_index within a level is
        // lex-compatible, which the triangular solve relies on.
        entries.sort_by_key(|e| (e.level, e.index));
        let dim = match basis {
            LogSigBasis::Expanded => spec.sig_len(),
            _ => witt_dimension(d, n),
        };
        Ok(LogSigPlan { spec: spec.clone(), basis, entries, dim })
    }

    /// Output dimension of the projection.
    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn basis(&self) -> LogSigBasis {
        self.basis
    }

    pub fn spec(&self) -> &SigSpec {
        &self.spec
    }

    /// A plan is only valid for the `SigSpec` it was built from (same `d`
    /// and `depth`); projecting through it with another spec would gather
    /// wrong indices. Callers that accept a caller-supplied plan must run
    /// this check rather than trusting the buffer lengths to disagree.
    pub fn check_compatible(&self, spec: &SigSpec) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.spec.d() == spec.d() && self.spec.depth() == spec.depth(),
            "LogSigPlan built for (d={}, depth={}) used with a (d={}, depth={}) signature",
            self.spec.d(),
            self.spec.depth(),
            spec.d(),
            spec.depth()
        );
        Ok(())
    }

    /// `(level, index-within-level)` of each Lyndon word, in output order.
    pub fn lyndon_positions(&self) -> Vec<(usize, usize)> {
        self.entries.iter().map(|e| (e.level, e.index)).collect()
    }

    /// Project a log tensor onto the plan's basis coefficients. Generic
    /// over the element precision: the plan itself is static index data
    /// (the `f32` bracket coefficients widen losslessly to `f64` through
    /// `E::from_f32`, the identity at `f32`).
    pub fn project<E: Elem>(&self, logtensor: &[E]) -> Vec<E> {
        debug_assert_eq!(logtensor.len(), self.spec.sig_len());
        match self.basis {
            LogSigBasis::Expanded => logtensor.to_vec(),
            LogSigBasis::Words => self
                .entries
                .iter()
                .map(|e| self.spec.level(logtensor, e.level)[e.index])
                .collect(),
            LogSigBasis::Lyndon => {
                let mut residual = logtensor.to_vec();
                let mut out = vec![E::ZERO; self.dim];
                self.project_into(&mut residual, &mut out);
                out
            }
        }
    }

    /// [`Self::project`] into a caller buffer, allocation-free: the
    /// batched logsignature epilogue and `Path::logsig_query_into` call
    /// this once per lane/query with reused buffers. The Lyndon basis
    /// runs its forward substitution in place, so `logtensor` is consumed
    /// as scratch (its contents are unspecified afterwards); Expanded and
    /// Words leave it untouched. Bitwise identical to [`Self::project`].
    pub fn project_into<E: Elem>(&self, logtensor: &mut [E], out: &mut [E]) {
        debug_assert_eq!(logtensor.len(), self.spec.sig_len());
        debug_assert_eq!(out.len(), self.dim);
        match self.basis {
            LogSigBasis::Expanded => out.copy_from_slice(logtensor),
            LogSigBasis::Words => {
                for (o, e) in out.iter_mut().zip(&self.entries) {
                    *o = self.spec.level(logtensor, e.level)[e.index];
                }
            }
            LogSigBasis::Lyndon => {
                // Forward substitution: φ(ℓ) = ℓ + (lex-later words), so
                // processing Lyndon words of each level in increasing index
                // order peels coefficients one at a time.
                for (o, e) in out.iter_mut().zip(&self.entries) {
                    let lvl = self.spec.level_mut(logtensor, e.level);
                    let alpha = lvl[e.index];
                    *o = alpha;
                    if alpha != E::ZERO {
                        for &(idx, coeff) in &e.expansion {
                            lvl[idx] -= alpha * E::from_f32(coeff);
                        }
                    }
                }
            }
        }
    }

    /// VJP of [`Self::project`]: cotangent on coefficients → cotangent on
    /// the log tensor. (The projection is linear, so this is its
    /// transpose.)
    pub fn project_vjp<E: Elem>(&self, g: &[E]) -> Vec<E> {
        debug_assert_eq!(g.len(), self.dim);
        match self.basis {
            LogSigBasis::Expanded => g.to_vec(),
            LogSigBasis::Words => {
                let mut out = self.spec.zeros_elem::<E>();
                for (e, &gv) in self.entries.iter().zip(g) {
                    self.spec.level_mut(&mut out, e.level)[e.index] += gv;
                }
                out
            }
            LogSigBasis::Lyndon => {
                // Transpose of the forward substitution, processed in
                // reverse entry order. Forward step j:
                //   α_j = r[pos_j];  r -= α_j · φ_j.
                // Reverse: g_r starts at 0; for j = last..first:
                //   gα_total = g[j] - <φ_j, g_r>;  g_r[pos_j] += gα_total.
                let mut gr = self.spec.zeros_elem::<E>();
                for (e, &gv) in self.entries.iter().zip(g).rev() {
                    let lvl = self.spec.level_mut(&mut gr, e.level);
                    let mut g_alpha = gv;
                    for &(idx, coeff) in &e.expansion {
                        g_alpha -= E::from_f32(coeff) * lvl[idx];
                    }
                    lvl[e.index] += g_alpha;
                }
                gr
            }
        }
    }

    /// Rebuild the full log tensor from Lyndon-basis coefficients
    /// (`Σ α_ℓ φ(ℓ)`). Test/diagnostic helper; requires `Lyndon` basis.
    pub fn lyndon_reconstruct(&self, alpha: &[f32]) -> Vec<f32> {
        assert_eq!(self.basis, LogSigBasis::Lyndon);
        assert_eq!(alpha.len(), self.dim);
        let mut out = self.spec.zeros();
        for (e, &a) in self.entries.iter().zip(alpha) {
            let lvl = self.spec.level_mut(&mut out, e.level);
            for &(idx, coeff) in &e.expansion {
                lvl[idx] += a * coeff;
            }
        }
        out
    }
}

/// Concurrent per-`(d, depth)` cache of **Words-basis** plans: one build
/// amortises across every subsequent call — Signatory/iisignature's
/// precompute-then-reuse strategy, packaged once so its users (the
/// coordinator's router + native microbatch backend, deepsig's logsig
/// readout) cannot drift apart.
#[derive(Default)]
pub struct WordsPlanCache {
    plans: Mutex<HashMap<(usize, usize), Arc<LogSigPlan>>>,
}

impl WordsPlanCache {
    pub fn new() -> WordsPlanCache {
        WordsPlanCache::default()
    }

    /// The cached Words-basis plan for `(d, depth)`, building it on first
    /// use. Errors on an invalid spec.
    pub fn get(&self, d: usize, depth: usize) -> anyhow::Result<Arc<LogSigPlan>> {
        let mut plans = self.plans.lock().unwrap();
        if let Some(p) = plans.get(&(d, depth)) {
            // Cache integrity: an entry filed under the wrong key must
            // error, never silently gather wrong indices. Field checks
            // only — no SigSpec construction on the hot hit path.
            anyhow::ensure!(
                p.spec().d() == d && p.spec().depth() == depth,
                "plan cache corrupted: entry for (d={d}, depth={depth}) was built for \
                 (d={}, depth={})",
                p.spec().d(),
                p.spec().depth()
            );
            return Ok(Arc::clone(p));
        }
        let spec = SigSpec::new(d, depth)?;
        let plan = Arc::new(LogSigPlan::new(&spec, LogSigBasis::Words)?);
        plans.insert((d, depth), Arc::clone(&plan));
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::propcheck::assert_close;
    use crate::substrate::rng::Rng;

    #[test]
    fn plan_dims() {
        let spec = SigSpec::new(2, 5).unwrap();
        assert_eq!(LogSigPlan::new(&spec, LogSigBasis::Expanded).unwrap().dim(), spec.sig_len());
        assert_eq!(LogSigPlan::new(&spec, LogSigBasis::Lyndon).unwrap().dim(), 14);
        assert_eq!(LogSigPlan::new(&spec, LogSigBasis::Words).unwrap().dim(), 14);
    }

    #[test]
    fn entries_sorted_by_level_then_index() {
        let spec = SigSpec::new(3, 4).unwrap();
        let plan = LogSigPlan::new(&spec, LogSigBasis::Words).unwrap();
        let pos = plan.lyndon_positions();
        for w in pos.windows(2) {
            assert!(w[0] < w[1], "entries out of order: {:?} {:?}", w[0], w[1]);
        }
    }

    #[test]
    fn project_into_matches_project_bitwise() {
        // A dirty out buffer and a reused scratch must never change a bit
        // relative to the allocating projection, in any basis.
        let spec = SigSpec::new(3, 4).unwrap();
        let mut rng = Rng::new(17);
        for basis in [LogSigBasis::Expanded, LogSigBasis::Lyndon, LogSigBasis::Words] {
            let plan = LogSigPlan::new(&spec, basis).unwrap();
            let mut out = vec![f32::NAN; plan.dim()]; // dirty on purpose
            for _ in 0..4 {
                let x = rng.normal_vec(spec.sig_len(), 1.0);
                let want = plan.project(&x);
                let mut scratch = x.clone();
                plan.project_into(&mut scratch, &mut out);
                assert_eq!(out, want, "{basis:?}");
            }
        }
    }

    #[test]
    fn project_vjp_is_transpose_of_project() {
        // <project(x), g> == <x, project_vjp(g)> for all bases (linearity).
        let spec = SigSpec::new(3, 4).unwrap();
        let mut rng = Rng::new(11);
        for basis in [LogSigBasis::Expanded, LogSigBasis::Lyndon, LogSigBasis::Words] {
            let plan = LogSigPlan::new(&spec, basis).unwrap();
            for _ in 0..5 {
                let x = rng.normal_vec(spec.sig_len(), 1.0);
                let g = rng.normal_vec(plan.dim(), 1.0);
                let lhs: f64 = plan
                    .project(&x)
                    .iter()
                    .zip(&g)
                    .map(|(&a, &b)| a as f64 * b as f64)
                    .sum();
                let rhs: f64 = x
                    .iter()
                    .zip(&plan.project_vjp(&g))
                    .map(|(&a, &b)| a as f64 * b as f64)
                    .sum();
                assert!(
                    (lhs - rhs).abs() < 1e-3 * (1.0 + lhs.abs()),
                    "{basis:?}: <Px,g>={lhs} <x,P'g>={rhs}"
                );
            }
        }
    }

    #[test]
    fn lyndon_project_then_reconstruct_roundtrips_on_lie_elements() {
        // For an element in the image of φ (a genuine log-signature), the
        // projection followed by reconstruction is the identity.
        let spec = SigSpec::new(2, 4).unwrap();
        let plan = LogSigPlan::new(&spec, LogSigBasis::Lyndon).unwrap();
        let mut rng = Rng::new(3);
        // Build a random Lie element via reconstruction from random α.
        let alpha = rng.normal_vec(plan.dim(), 1.0);
        let lie = plan.lyndon_reconstruct(&alpha);
        let back = plan.project(&lie);
        assert_close(&back, &alpha, 1e-4, 1e-5);
    }

    #[test]
    fn words_project_is_exact_gather() {
        let spec = SigSpec::new(2, 3).unwrap();
        let plan = LogSigPlan::new(&spec, LogSigBasis::Words).unwrap();
        let x: Vec<f32> = (0..spec.sig_len()).map(|i| i as f32).collect();
        let z = plan.project(&x);
        // Lyndon words over {0,1} up to length 3: 0, 1, 01, 001, 011.
        // Flat positions: level1: 0,1 → x[0], x[1];
        // level2 word 01 → index 1 → x[2 + 1] = 3;
        // level3 words 001 (idx 1), 011 (idx 3) → x[6+1], x[6+3].
        assert_eq!(z, vec![0.0, 1.0, 3.0, 7.0, 9.0]);
    }

    #[test]
    fn words_plan_cache_builds_once_and_validates() {
        let cache = WordsPlanCache::new();
        let a = cache.get(2, 3).unwrap();
        let b = cache.get(2, 3).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second get must reuse the cached plan");
        assert_eq!(a.dim(), crate::words::witt_dimension(2, 3));
        let c = cache.get(3, 4).unwrap();
        assert_eq!(c.dim(), crate::words::witt_dimension(3, 4));
        assert!(cache.get(0, 3).is_err(), "invalid spec is a clean error");
    }

    #[test]
    fn d1_plans() {
        // One channel: the only Lyndon word is "0", dim 1 in compressed
        // bases at any depth.
        let spec = SigSpec::new(1, 6).unwrap();
        for basis in [LogSigBasis::Lyndon, LogSigBasis::Words] {
            let plan = LogSigPlan::new(&spec, basis).unwrap();
            assert_eq!(plan.dim(), 1);
            let x = vec![3.0, 0.0, 0.0, 0.0, 0.0, 0.0];
            assert_eq!(plan.project(&x), vec![3.0]);
        }
    }
}
