//! The logsignature transform (§2.3) in three bases (§4.3, App. A.2):
//!
//! - [`LogSigBasis::Expanded`] — the raw `log(Sig)` tensor, dimension
//!   `sig_len` (Signatory's `mode="expand"`).
//! - [`LogSigBasis::Lyndon`] — coefficients with respect to the Lyndon
//!   (Hall) basis `φ(ℓ)`, dimension `w(d, N)`; what `iisignature` computes.
//!   Recovered by forward substitution using the triangularity of `φ`.
//! - [`LogSigBasis::Words`] — the paper's **new, more efficient basis**
//!   (§4.3, App. A.2.3): coefficients are simply the log tensor's entries
//!   at Lyndon-word indices, `z = ψ(log Sig)`. Same dimension `w(d, N)`,
//!   but projection is a gather instead of a triangular solve.
//!
//! A [`LogSigPlan`] precomputes the per-(d, N, basis) static data (Lyndon
//! words, flat indices, and — for the Lyndon basis only — the bracket
//! expansions), mirroring Signatory's `LogSignature` class which amortises
//! the same preparation across calls.
//!
//! Batched logsignatures execute through the **execution planner**
//! ([`crate::exec`]) exactly like the signature side: [`batch`] runs the
//! same [`crate::exec::ExecPlan`]s via the shared planned signature
//! executors, followed by a per-lane log + basis-projection epilogue that
//! is bitwise identical to the scalar path. The coordinator serves
//! `LogSignature` requests through the same adaptive microbatcher as
//! `Signature` requests on top of these entry points.

pub mod batch;
pub mod plan;

pub use batch::{
    logsignature_batch, logsignature_batch_planned, logsignature_batch_vjp,
    logsignature_batch_vjp_planned, logsignature_batch_with,
};
pub use plan::{LogSigBasis, LogSigPlan, WordsPlanCache};

use crate::signature::backward::signature_vjp_with;
use crate::signature::forward::signature_with;
use crate::signature::SigConfig;
use crate::ta::log::{log_into, log_into_ws, log_vjp, LogWorkspace};
use crate::ta::{Elem, SigSpec};

/// `LogSig^N(path)` in the plan's basis.
///
/// Panics on a mismatched plan or malformed path.
#[deprecated(note = "panics on malformed input; use `logsignature_with` (PR 3 panic-safety \
                     contract: every serving-reachable entry point is fallible)")]
pub fn logsignature(path: &[f32], stream: usize, spec: &SigSpec, plan: &LogSigPlan) -> Vec<f32> {
    logsignature_with(path, stream, spec, plan, &SigConfig::serial())
        .expect("valid path and a LogSigPlan built for this SigSpec")
}

/// `LogSig^N(path)` honouring a [`SigConfig`] (threads / basepoint /
/// initial / inverse), fallible: a mismatched plan, malformed path buffer,
/// or bad basepoint/initial shape is an `Err`, never a panic. The fallible
/// mirror of the deprecated [`logsignature`], completing the panic-safety
/// contract across every logsignature entry point. Generic over the
/// element precision (bare `&[f32]` call sites infer `E = f32`): the f64
/// instantiation runs the same signature sweep, tensor log, and basis
/// projection in double precision — the serving layer's f64 logsignature
/// arm is exactly this function at `E = f64`.
pub fn logsignature_with<E: Elem>(
    path: &[E],
    stream: usize,
    spec: &SigSpec,
    plan: &LogSigPlan,
    cfg: &SigConfig,
) -> anyhow::Result<Vec<E>> {
    plan.check_compatible(spec)?;
    let sig = signature_with(path, stream, spec, cfg)?;
    logsignature_from_sig(&sig, spec, plan)
}

/// Reusable scratch for allocation-free logsignature work: one signature
/// buffer, one log-tensor buffer, and the tensor-log Horner workspace.
/// `Path::logsig_query_into` and the batched epilogue thread one of these
/// through repeated queries/lanes so the hot path allocates nothing.
/// Generic over the element precision (`f32` default keeps existing call
/// sites unchanged).
pub struct LogSigWorkspace<E: Elem = f32> {
    pub(crate) sig: Vec<E>,
    pub(crate) logtensor: Vec<E>,
    pub(crate) lw: LogWorkspace<E>,
}

impl<E: Elem> LogSigWorkspace<E> {
    pub fn new(spec: &SigSpec) -> LogSigWorkspace<E> {
        LogSigWorkspace {
            sig: spec.zeros_elem::<E>(),
            logtensor: spec.zeros_elem::<E>(),
            lw: LogWorkspace::new(spec),
        }
    }

    /// Errors unless this workspace was sized for `spec` (reusing one
    /// across specs would slice-panic deep inside the log kernels).
    pub fn check_spec(&self, spec: &SigSpec) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.sig.len() == spec.sig_len() && self.lw.fits(spec),
            "LogSigWorkspace sized for sig_len {}, used with sig_len {}",
            self.sig.len(),
            spec.sig_len()
        );
        Ok(())
    }

    /// The internal signature buffer (callers stage the queried signature
    /// here before [`LogSigWorkspace::project_sig_into`]).
    pub(crate) fn sig_mut(&mut self) -> &mut [E] {
        &mut self.sig
    }

    /// `out = plan.project(log(self.sig))`, zero allocations. The caller
    /// has already validated plan/spec compatibility and buffer sizes.
    pub(crate) fn project_sig_into(&mut self, spec: &SigSpec, plan: &LogSigPlan, out: &mut [E]) {
        log_into_ws(spec, &self.sig, &mut self.logtensor, &mut self.lw);
        plan.project_into(&mut self.logtensor, out);
    }
}

/// Logsignature of an already-computed signature (used by the Path class
/// and the coordinator, where the signature is already available). Errors
/// if `plan` was built for a different `SigSpec` (a mismatched plan would
/// otherwise silently gather wrong indices) or the signature buffer has
/// the wrong length.
pub fn logsignature_from_sig<E: Elem>(
    sig: &[E],
    spec: &SigSpec,
    plan: &LogSigPlan,
) -> anyhow::Result<Vec<E>> {
    plan.check_compatible(spec)?;
    anyhow::ensure!(
        sig.len() == spec.sig_len(),
        "signature has {} values, expected {}",
        sig.len(),
        spec.sig_len()
    );
    let mut logtensor = spec.zeros_elem::<E>();
    log_into(spec, sig, &mut logtensor);
    Ok(plan.project(&logtensor))
}

/// Stream mode for the logsignature (Signatory's `logsignature(...,
/// stream=True)`): the logsignature of every prefix, `(stream-1, dim)`.
/// One O(L) signature sweep, then a log + projection per prefix.
pub fn logsignature_stream(
    path: &[f32],
    stream: usize,
    spec: &SigSpec,
    plan: &LogSigPlan,
) -> anyhow::Result<Vec<f32>> {
    plan.check_compatible(spec)?;
    // Fallible stream entry point: a malformed path buffer is an Err here,
    // not a panic inherited from the infallible `signature_stream`.
    let sigs =
        crate::signature::signature_stream_with(path, stream, spec, &SigConfig::serial())?;
    let len = spec.sig_len();
    let dim = plan.dim();
    let mut out = vec![0.0f32; (stream - 1) * dim];
    let mut logtensor = spec.zeros();
    for i in 0..stream - 1 {
        log_into(spec, &sigs[i * len..(i + 1) * len], &mut logtensor);
        out[i * dim..(i + 1) * dim].copy_from_slice(&plan.project(&logtensor));
    }
    Ok(out)
}

/// VJP of the logsignature: given the cotangent `g` in the plan's basis,
/// returns `∂L/∂path`. Serial; panics on mismatched buffers.
#[deprecated(note = "panics on malformed input; use `logsignature_vjp_with` (fallible and \
                     thread-configurable)")]
pub fn logsignature_vjp(
    path: &[f32],
    stream: usize,
    spec: &SigSpec,
    plan: &LogSigPlan,
    g: &[f32],
) -> Vec<f32> {
    logsignature_vjp_with(path, stream, spec, plan, &SigConfig::serial(), g)
        .expect("valid path and cotangent")
}

/// VJP of the logsignature honouring a [`SigConfig`] (threads / basepoint
/// / initial / inverse). `cfg.threads > 1` runs both the forward signature
/// and the signature VJP stream-parallel (chunked Chen identity; see
/// [`crate::signature::backward`]); the log/projection VJP itself is a
/// cheap O(sig_len) epilogue. Returns `∂L/∂path`; cotangents on a
/// configured basepoint/initial are dropped (call
/// [`crate::signature::signature_vjp_with`] directly if you need them).
pub fn logsignature_vjp_with(
    path: &[f32],
    stream: usize,
    spec: &SigSpec,
    plan: &LogSigPlan,
    cfg: &SigConfig,
    g: &[f32],
) -> anyhow::Result<Vec<f32>> {
    plan.check_compatible(spec)?;
    anyhow::ensure!(
        g.len() == plan.dim(),
        "cotangent has {} values, expected basis dimension {}",
        g.len(),
        plan.dim()
    );
    let sig = signature_with(path, stream, spec, cfg)?;
    let g_sig = logsignature_from_sig_vjp(&sig, spec, plan, g)?;
    Ok(signature_vjp_with(path, stream, spec, cfg, &g_sig)?.grad_path)
}

/// VJP of [`logsignature_from_sig`]: cotangent on the basis coefficients →
/// cotangent on the signature. Errors on a plan built for a different
/// `SigSpec` or a mismatched cotangent length (like the forward).
pub fn logsignature_from_sig_vjp(
    sig: &[f32],
    spec: &SigSpec,
    plan: &LogSigPlan,
    g: &[f32],
) -> anyhow::Result<Vec<f32>> {
    plan.check_compatible(spec)?;
    anyhow::ensure!(
        g.len() == plan.dim(),
        "cotangent has {} values, expected basis dimension {}",
        g.len(),
        plan.dim()
    );
    let g_logtensor = plan.project_vjp(g);
    let mut g_sig = spec.zeros();
    log_vjp(spec, sig, &g_logtensor, &mut g_sig);
    Ok(g_sig)
}

#[cfg(test)]
#[allow(deprecated)] // the panicking wrappers stay covered until removed
mod tests {
    use super::*;
    use crate::substrate::propcheck::{assert_close, property};
    use crate::substrate::rng::Rng;
    use crate::words::witt_dimension;

    fn random_path(rng: &mut Rng, stream: usize, d: usize) -> Vec<f32> {
        let mut p = vec![0.0f32; stream * d];
        for i in 1..stream {
            for c in 0..d {
                p[i * d + c] = p[(i - 1) * d + c] + rng.normal_f32() * 0.3;
            }
        }
        p
    }

    #[test]
    fn dimensions_per_basis() {
        let spec = SigSpec::new(3, 4).unwrap();
        for (basis, dim) in [
            (LogSigBasis::Expanded, spec.sig_len()),
            (LogSigBasis::Lyndon, witt_dimension(3, 4)),
            (LogSigBasis::Words, witt_dimension(3, 4)),
        ] {
            let plan = LogSigPlan::new(&spec, basis).unwrap();
            assert_eq!(plan.dim(), dim, "{basis:?}");
            let mut rng = Rng::new(1);
            let path = random_path(&mut rng, 6, 3);
            assert_eq!(logsignature(&path, 6, &spec, &plan).len(), dim);
        }
    }

    #[test]
    fn lyndon_reconstruction_recovers_log_tensor() {
        // Σ_ℓ α_ℓ φ(ℓ) must equal log(Sig): the defining property of the
        // Lyndon-basis coefficients (eq. 17).
        property("lyndon reconstructs log", 10, |g| {
            let d = g.usize_in(2, 3);
            let n = g.usize_in(1, 4);
            let stream = g.usize_in(2, 8);
            g.label(format!("d={d} n={n} stream={stream}"));
            let spec = SigSpec::new(d, n).unwrap();
            let plan = LogSigPlan::new(&spec, LogSigBasis::Lyndon).unwrap();
            let path = random_path(g.rng(), stream, d);
            let sig = crate::signature::signature(&path, stream, &spec);
            let logtensor = crate::ta::log(&spec, &sig);
            let alpha = logsignature(&path, stream, &spec, &plan);
            let rebuilt = plan.lyndon_reconstruct(&alpha);
            assert_close(&rebuilt, &logtensor, 2e-3, 1e-4);
        });
    }

    #[test]
    fn words_basis_is_gather_of_log_tensor() {
        let spec = SigSpec::new(3, 3).unwrap();
        let plan = LogSigPlan::new(&spec, LogSigBasis::Words).unwrap();
        let mut rng = Rng::new(7);
        let path = random_path(&mut rng, 5, 3);
        let sig = crate::signature::signature(&path, 5, &spec);
        let logtensor = crate::ta::log(&spec, &sig);
        let z = logsignature(&path, 5, &spec, &plan);
        for (i, &(level, idx)) in plan.lyndon_positions().iter().enumerate() {
            assert_eq!(z[i], spec.level(&logtensor, level)[idx]);
        }
    }

    #[test]
    fn bases_agree_at_depth_two() {
        // At N ≤ 2 the triangular change of basis is the identity, so
        // Lyndon and Words coefficients coincide.
        property("lyndon == words at N<=2", 10, |g| {
            let d = g.usize_in(2, 4);
            let n = g.usize_in(1, 2);
            let stream = g.usize_in(2, 8);
            let spec = SigSpec::new(d, n).unwrap();
            let path = random_path(g.rng(), stream, d);
            let lyndon =
                logsignature(&path, stream, &spec, &LogSigPlan::new(&spec, LogSigBasis::Lyndon).unwrap());
            let words =
                logsignature(&path, stream, &spec, &LogSigPlan::new(&spec, LogSigBasis::Words).unwrap());
            assert_close(&lyndon, &words, 1e-5, 1e-6);
        });
    }

    #[test]
    fn level_one_is_total_increment() {
        // In every basis the level-1 coefficients are x_L - x_1.
        let spec = SigSpec::new(3, 3).unwrap();
        let mut rng = Rng::new(3);
        let path = random_path(&mut rng, 9, 3);
        for basis in [LogSigBasis::Expanded, LogSigBasis::Lyndon, LogSigBasis::Words] {
            let plan = LogSigPlan::new(&spec, basis).unwrap();
            let z = logsignature(&path, 9, &spec, &plan);
            for c in 0..3 {
                let expect = path[8 * 3 + c] - path[c];
                assert!((z[c] - expect).abs() < 1e-4, "{basis:?} channel {c}");
            }
        }
    }

    #[test]
    fn one_segment_logsignature_is_increment_only() {
        // log(exp(z)) = z in level 1, zeros above: so every basis gives the
        // increment then zeros.
        let spec = SigSpec::new(2, 4).unwrap();
        let path = [0.0f32, 0.0, 0.7, -0.3];
        for basis in [LogSigBasis::Lyndon, LogSigBasis::Words] {
            let plan = LogSigPlan::new(&spec, basis).unwrap();
            let z = logsignature(&path, 2, &spec, &plan);
            assert!((z[0] - 0.7).abs() < 1e-5);
            assert!((z[1] + 0.3).abs() < 1e-5);
            for &v in &z[2..] {
                assert!(v.abs() < 1e-5, "{basis:?}: higher coefficient {v}");
            }
        }
    }

    #[test]
    fn stream_mode_matches_prefix_recomputation() {
        let spec = SigSpec::new(3, 3).unwrap();
        let plan = LogSigPlan::new(&spec, LogSigBasis::Words).unwrap();
        let mut rng = Rng::new(12);
        let stream = 8;
        let path = random_path(&mut rng, stream, 3);
        let st = logsignature_stream(&path, stream, &spec, &plan).unwrap();
        let dim = plan.dim();
        for j in 2..=stream {
            let direct = logsignature(&path[..j * 3], j, &spec, &plan);
            assert_close(&st[(j - 2) * dim..(j - 1) * dim], &direct, 2e-3, 2e-4);
        }
    }

    #[test]
    fn parallel_vjp_matches_serial_all_bases() {
        // The chunked Chen-identity backward, reached through the
        // logsignature VJP, agrees with the serial sweep.
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(40);
        let stream = 72;
        let path = random_path(&mut rng, stream, 2);
        for basis in [LogSigBasis::Expanded, LogSigBasis::Lyndon, LogSigBasis::Words] {
            let plan = LogSigPlan::new(&spec, basis).unwrap();
            let g = rng.normal_vec(plan.dim(), 1.0);
            let serial = logsignature_vjp(&path, stream, &spec, &plan, &g);
            let par = logsignature_vjp_with(
                &path,
                stream,
                &spec,
                &plan,
                &SigConfig::parallel(4),
                &g,
            )
            .unwrap();
            assert_close(&par, &serial, 2e-3, 1e-4);
        }
    }

    #[test]
    fn mismatched_plan_is_rejected_not_misread() {
        // A plan built for another (d, depth) must error, never silently
        // gather wrong indices — even when buffer lengths happen to line
        // up by accident.
        let spec = SigSpec::new(3, 3).unwrap();
        let wrong_d = LogSigPlan::new(&SigSpec::new(2, 3).unwrap(), LogSigBasis::Words).unwrap();
        let wrong_depth = LogSigPlan::new(&SigSpec::new(3, 2).unwrap(), LogSigBasis::Words).unwrap();
        let sig = vec![0.0f32; spec.sig_len()];
        assert!(logsignature_from_sig(&sig, &spec, &wrong_d).is_err());
        assert!(logsignature_from_sig(&sig, &spec, &wrong_depth).is_err());
        // Wrong signature buffer length is also a clean error.
        let right = LogSigPlan::new(&spec, LogSigBasis::Words).unwrap();
        assert!(logsignature_from_sig(&sig[..spec.sig_len() - 1], &spec, &right).is_err());
        let path = vec![0.0f32; 4 * 3];
        assert!(logsignature_stream(&path, 4, &spec, &wrong_d).is_err());
        // Malformed path buffers are Err too (previously a panic inherited
        // from the infallible signature_stream).
        let plan = LogSigPlan::new(&spec, LogSigBasis::Words).unwrap();
        assert!(logsignature_stream(&path[..3], 4, &spec, &plan).is_err());
        assert!(logsignature_stream(&path[..2], 1, &spec, &plan).is_err());
        let g = vec![0.0f32; wrong_d.dim()];
        assert!(
            logsignature_vjp_with(&path, 4, &spec, &wrong_d, &SigConfig::serial(), &g).is_err()
        );
        assert!(logsignature_from_sig_vjp(&sig, &spec, &wrong_d, &g).is_err());
    }

    #[test]
    fn logsignature_with_matches_wrapper_and_validates() {
        let spec = SigSpec::new(2, 3).unwrap();
        let plan = LogSigPlan::new(&spec, LogSigBasis::Words).unwrap();
        let mut rng = Rng::new(51);
        let path = random_path(&mut rng, 7, 2);
        let fallible =
            logsignature_with(&path, 7, &spec, &plan, &SigConfig::serial()).unwrap();
        assert_eq!(fallible, logsignature(&path, 7, &spec, &plan));
        // Basepoint config threads through to the signature layer.
        let cfg = SigConfig { basepoint: Some(vec![0.1, -0.2]), ..SigConfig::serial() };
        let with_bp = logsignature_with(&path, 7, &spec, &plan, &cfg).unwrap();
        let mut prepended = vec![0.1, -0.2];
        prepended.extend_from_slice(&path);
        assert_close(
            &with_bp,
            &logsignature(&prepended, 8, &spec, &plan),
            1e-4,
            1e-5,
        );
        // Every malformed input is an Err, not a panic.
        assert!(logsignature_with(&path[..3], 7, &spec, &plan, &SigConfig::serial()).is_err());
        assert!(logsignature_with(&path[..2], 1, &spec, &plan, &SigConfig::serial()).is_err());
        let wrong = LogSigPlan::new(&SigSpec::new(3, 3).unwrap(), LogSigBasis::Words).unwrap();
        assert!(logsignature_with(&path, 7, &spec, &wrong, &SigConfig::serial()).is_err());
        let bad_bp = SigConfig { basepoint: Some(vec![0.0; 3]), ..SigConfig::serial() };
        assert!(logsignature_with(&path, 7, &spec, &plan, &bad_bp).is_err());
    }

    #[test]
    fn workspace_spec_check() {
        let spec = SigSpec::new(2, 3).unwrap();
        let other = SigSpec::new(3, 4).unwrap();
        let ws: LogSigWorkspace = LogSigWorkspace::new(&spec);
        assert!(ws.check_spec(&spec).is_ok());
        assert!(ws.check_spec(&other).is_err());
    }

    #[test]
    fn vjp_rejects_mismatched_cotangent() {
        let spec = SigSpec::new(2, 3).unwrap();
        let plan = LogSigPlan::new(&spec, LogSigBasis::Words).unwrap();
        let path = vec![0.0f32; 6 * 2];
        let bad = vec![0.0f32; plan.dim() + 1];
        assert!(
            logsignature_vjp_with(&path, 6, &spec, &plan, &SigConfig::serial(), &bad).is_err()
        );
        // Bad path buffers error too (propagated from the signature layer).
        let good = vec![0.0f32; plan.dim()];
        assert!(
            logsignature_vjp_with(&path, 7, &spec, &plan, &SigConfig::serial(), &good).is_err()
        );
    }

    #[test]
    fn vjp_matches_finite_differences_all_bases() {
        for basis in [LogSigBasis::Expanded, LogSigBasis::Lyndon, LogSigBasis::Words] {
            let spec = SigSpec::new(2, 3).unwrap();
            let plan = LogSigPlan::new(&spec, basis).unwrap();
            let mut rng = Rng::new(13);
            let stream = 5;
            let path = random_path(&mut rng, stream, 2);
            let g = rng.normal_vec(plan.dim(), 1.0);
            let grad = logsignature_vjp(&path, stream, &spec, &plan, &g);
            let h = 1e-2f32;
            for i in 0..path.len() {
                let mut pp = path.clone();
                pp[i] += h;
                let mut pm = path.clone();
                pm[i] -= h;
                let fd: f32 = logsignature(&pp, stream, &spec, &plan)
                    .iter()
                    .zip(logsignature(&pm, stream, &spec, &plan).iter())
                    .zip(&g)
                    .map(|((&a, &b), &gv)| (a - b) / (2.0 * h) * gv)
                    .sum();
                assert!(
                    (fd - grad[i]).abs() < 4e-2 * (1.0 + fd.abs()),
                    "{basis:?} grad[{i}]: fd={fd} vjp={}",
                    grad[i]
                );
            }
        }
    }
}
