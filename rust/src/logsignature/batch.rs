//! Batched logsignatures through the execution planner.
//!
//! Logsignature parity with the signature side: these entry points execute
//! the *same* [`ExecPlan`]s via the shared planned signature executors
//! ([`crate::signature::signature_batch_planned`] /
//! [`crate::signature::signature_batch_vjp_planned`]), then apply a
//! per-lane log + basis-projection epilogue:
//!
//! - `LaneFused` runs the lane-interleaved signature sweep — bitwise
//!   identical per lane to scalar dispatch — and the epilogue replays the
//!   scalar `log_into` + projection per lane, so a batched logsignature is
//!   **bitwise identical** per lane to [`super::logsignature_with`] in
//!   every basis (pinned by property tests).
//! - `StreamParallel` reuses the chunked Chen-identity forward/backward
//!   inside each path; the log/projection epilogue is an O(sig_len)
//!   per-lane postscript either way.
//! - The lane-fused backward applies at **every** `d` — the scalar VJP's
//!   monomorphised bodies (`d ≤` [`crate::exec::LANE_VJP_MAX_D`]) and the
//!   runtime-`d` body beyond share one op order with the lane kernels —
//!   and the cotangent this module hands the signature VJP is just a
//!   transformed tensor (`project_vjp` then `log_vjp`), so logsig needs
//!   nothing dimension-specific of its own.
//!
//! The coordinator's native microbatcher executes flushed `LogSignature`
//! microbatches through [`logsignature_batch_planned`], so serving rows
//! are bitwise identical to direct scalar serves.

use super::plan::LogSigPlan;
use crate::exec::{ExecPlan, ExecPlanner, WorkShape};
use crate::signature::{signature_batch_planned, signature_batch_vjp_planned, SigConfig};
use crate::ta::log::{log_into_ws, log_vjp, LogWorkspace};
use crate::ta::{Elem, SigSpec};

/// Batched logsignature over a `(batch, stream, d)` buffer. Returns
/// `(batch, plan.dim())`. Strategy selection goes through
/// [`crate::exec::ExecPlanner`]; `threads` workers share the lane blocks.
/// Generic over the element precision (`&[f32]` call sites infer
/// `E = f32` unchanged); the planner's shape carries `E::PRECISION`.
pub fn logsignature_batch<E: Elem>(
    paths: &[E],
    batch: usize,
    stream: usize,
    spec: &SigSpec,
    plan: &LogSigPlan,
    threads: usize,
) -> anyhow::Result<Vec<E>> {
    let cfg = SigConfig { threads, ..SigConfig::serial() };
    logsignature_batch_with(paths, batch, stream, spec, plan, &cfg)
}

/// Batched logsignature with full options (basepoint / initial / inverse
/// apply to every lane, exactly as in
/// [`crate::signature::signature_batch_with`]).
pub fn logsignature_batch_with<E: Elem>(
    paths: &[E],
    batch: usize,
    stream: usize,
    spec: &SigSpec,
    plan: &LogSigPlan,
    cfg: &SigConfig,
) -> anyhow::Result<Vec<E>> {
    let exec = ExecPlanner::new(cfg.threads).plan_forward(&WorkShape {
        batch,
        points: cfg.effective_len(stream),
        d: spec.d(),
        depth: spec.depth(),
        dtype: E::PRECISION,
    });
    logsignature_batch_planned(paths, batch, stream, spec, plan, cfg, exec)
}

/// Execute a batched logsignature under an explicit [`ExecPlan`] (the
/// coordinator's microbatch backend passes its serving plan here, so a
/// lone flushed row runs the scalar reference sweep). The signature sweep
/// executes the plan; the log + projection epilogue runs per lane with one
/// reused workspace — the same op sequence as the scalar path, so lanes
/// are bitwise identical to scalar logsignatures under `Scalar` and
/// `LaneFused` plans.
pub fn logsignature_batch_planned<E: Elem>(
    paths: &[E],
    batch: usize,
    stream: usize,
    spec: &SigSpec,
    plan: &LogSigPlan,
    cfg: &SigConfig,
    exec: ExecPlan,
) -> anyhow::Result<Vec<E>> {
    plan.check_compatible(spec)?;
    let sigs = signature_batch_planned(paths, batch, stream, spec, cfg, exec)?;
    let mut out = vec![E::ZERO; batch * plan.dim()];
    project_sigs_into(spec, plan, &sigs, batch, &mut out);
    Ok(out)
}

/// The per-lane log + basis-projection epilogue over `batch` packed
/// signatures, into `(batch, plan.dim())`: ONE definition of the
/// bitwise-parity-critical op sequence, shared by
/// [`logsignature_batch_planned`] and deepsig's lane-fused logsig-readout
/// train path. One reused [`LogWorkspace`] serves every lane; each lane
/// replays exactly the scalar `log_into` + `project` arithmetic. The
/// caller has validated plan/spec compatibility and buffer sizes.
pub(crate) fn project_sigs_into<E: Elem>(
    spec: &SigSpec,
    plan: &LogSigPlan,
    sigs: &[E],
    batch: usize,
    out: &mut [E],
) {
    let len = spec.sig_len();
    let dim = plan.dim();
    debug_assert_eq!(sigs.len(), batch * len);
    debug_assert_eq!(out.len(), batch * dim);
    let mut lw = LogWorkspace::new(spec);
    let mut logtensor = spec.zeros_elem::<E>();
    for b in 0..batch {
        log_into_ws(spec, &sigs[b * len..(b + 1) * len], &mut logtensor, &mut lw);
        plan.project_into(&mut logtensor, &mut out[b * dim..(b + 1) * dim]);
    }
}

/// Batched VJP of the logsignature: cotangents `g` of shape
/// `(batch, plan.dim())` in the plan's basis → `∂L/∂paths` of the input
/// shape. The forward signatures are recomputed (they feed the log VJP),
/// the O(sig_len) per-lane epilogue converts each basis cotangent into a
/// signature cotangent, and the batched signature VJP executes whatever
/// backward plan the planner picks — lane-fused at any `d` (bitwise
/// identical per lane to the serial [`super::logsignature_vjp_with`]),
/// chunked-Chen stream-parallel with surplus threads, per-path scalar
/// otherwise.
pub fn logsignature_batch_vjp(
    paths: &[f32],
    batch: usize,
    stream: usize,
    spec: &SigSpec,
    plan: &LogSigPlan,
    g: &[f32],
    threads: usize,
) -> anyhow::Result<Vec<f32>> {
    let planner = ExecPlanner::new(threads);
    let shape = WorkShape {
        batch,
        points: stream,
        d: spec.d(),
        depth: spec.depth(),
        dtype: crate::ta::Precision::F32,
    };
    logsignature_batch_vjp_planned(
        paths,
        batch,
        stream,
        spec,
        plan,
        g,
        threads,
        planner.plan_forward(&shape),
        planner.plan_backward(&shape),
    )
}

/// Execute a batched logsignature VJP under explicit forward/backward
/// [`ExecPlan`]s (see [`logsignature_batch_vjp`]).
#[allow(clippy::too_many_arguments)]
pub fn logsignature_batch_vjp_planned(
    paths: &[f32],
    batch: usize,
    stream: usize,
    spec: &SigSpec,
    plan: &LogSigPlan,
    g: &[f32],
    threads: usize,
    fwd: ExecPlan,
    bwd: ExecPlan,
) -> anyhow::Result<Vec<f32>> {
    plan.check_compatible(spec)?;
    let dim = plan.dim();
    anyhow::ensure!(
        g.len() == batch * dim,
        "cotangent has {} values, expected batch({batch}) * basis dimension({dim}) = {}",
        g.len(),
        batch * dim
    );
    let cfg = SigConfig { threads, ..SigConfig::serial() };
    // Forward signatures feed the log VJP; under Scalar/LaneFused plans
    // they are bitwise the scalar forward per lane.
    let sigs = signature_batch_planned(paths, batch, stream, spec, &cfg, fwd)?;
    let len = spec.sig_len();
    let mut g_sigs = vec![0.0f32; batch * len];
    for b in 0..batch {
        // Transpose of the projection, then the tensor-log VJP — the same
        // epilogue the scalar logsignature_vjp_with runs.
        let g_log = plan.project_vjp(&g[b * dim..(b + 1) * dim]);
        log_vjp(spec, &sigs[b * len..(b + 1) * len], &g_log, &mut g_sigs[b * len..(b + 1) * len]);
    }
    signature_batch_vjp_planned(paths, batch, stream, spec, &g_sigs, threads, bwd)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::LANE_BLOCK;
    use crate::logsignature::{logsignature_vjp_with, logsignature_with, LogSigBasis};
    use crate::substrate::propcheck::{assert_close, property};
    use crate::substrate::rng::Rng;

    fn random_batch(rng: &mut Rng, batch: usize, stream: usize, d: usize) -> Vec<f32> {
        let mut p = vec![0.0f32; batch * stream * d];
        for b in 0..batch {
            for i in 1..stream {
                for c in 0..d {
                    p[b * stream * d + i * d + c] =
                        p[b * stream * d + (i - 1) * d + c] + rng.normal_f32() * 0.3;
                }
            }
        }
        p
    }

    #[test]
    fn lane_fused_logsignature_is_bitwise_per_path_all_bases() {
        // The tentpole contract: a lane-fused batched logsignature must
        // reproduce the scalar path bit-for-bit in every basis, including
        // a ragged tail block past LANE_BLOCK.
        property("logsig batch == scalar bitwise", 8, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let batch = g.usize_in(2, 9);
            let stream = g.usize_in(2, 10);
            g.label(format!("d={d} n={n} batch={batch} stream={stream}"));
            let spec = SigSpec::new(d, n).unwrap();
            let paths = random_batch(g.rng(), batch, stream, d);
            let plen = stream * d;
            for basis in [LogSigBasis::Expanded, LogSigBasis::Lyndon, LogSigBasis::Words] {
                let plan = LogSigPlan::new(&spec, basis).unwrap();
                let dim = plan.dim();
                let out = logsignature_batch(&paths, batch, stream, &spec, &plan, 3).unwrap();
                for b in 0..batch {
                    let single = logsignature_with(
                        &paths[b * plen..(b + 1) * plen],
                        stream,
                        &spec,
                        &plan,
                        &SigConfig::serial(),
                    )
                    .unwrap();
                    assert_eq!(
                        &out[b * dim..(b + 1) * dim],
                        single.as_slice(),
                        "{basis:?} lane {b}"
                    );
                }
            }
        });
    }

    #[test]
    fn ragged_tail_block_stays_bitwise() {
        // LANE_BLOCK + 3 lanes on one thread force one full block and one
        // ragged tail block through the interleaved sweep.
        let spec = SigSpec::new(3, 3).unwrap();
        let plan = LogSigPlan::new(&spec, LogSigBasis::Words).unwrap();
        let mut rng = Rng::new(61);
        let (batch, stream) = (LANE_BLOCK + 3, 9);
        let paths = random_batch(&mut rng, batch, stream, 3);
        let plen = stream * 3;
        let dim = plan.dim();
        let out = logsignature_batch(&paths, batch, stream, &spec, &plan, 1).unwrap();
        for b in 0..batch {
            let single = logsignature_with(
                &paths[b * plen..(b + 1) * plen],
                stream,
                &spec,
                &plan,
                &SigConfig::serial(),
            )
            .unwrap();
            assert_eq!(&out[b * dim..(b + 1) * dim], single.as_slice(), "lane {b}");
        }
    }

    #[test]
    fn batch_with_options_is_bitwise_per_path() {
        let spec = SigSpec::new(2, 3).unwrap();
        let plan = LogSigPlan::new(&spec, LogSigBasis::Lyndon).unwrap();
        let mut rng = Rng::new(62);
        let (batch, stream) = (5, 7);
        let paths = random_batch(&mut rng, batch, stream, 2);
        let plen = stream * 2;
        let init = crate::signature::signature(&random_batch(&mut rng, 1, 4, 2), 4, &spec);
        for inverse in [false, true] {
            let cfg = SigConfig {
                basepoint: Some(vec![0.2, -0.3]),
                initial: Some(init.clone()),
                inverse,
                ..SigConfig::serial()
            };
            let out = logsignature_batch_with(&paths, batch, stream, &spec, &plan, &cfg).unwrap();
            let dim = plan.dim();
            for b in 0..batch {
                let single =
                    logsignature_with(&paths[b * plen..(b + 1) * plen], stream, &spec, &plan, &cfg)
                        .unwrap();
                assert_eq!(&out[b * dim..(b + 1) * dim], single.as_slice());
            }
        }
    }

    #[test]
    fn batch_vjp_is_bitwise_per_sample_on_the_lane_plan() {
        // threads <= batch at d <= LANE_VJP_MAX_D takes the lane-fused
        // backward; every sample's gradient must equal the serial scalar
        // logsignature VJP bit-for-bit, in every basis.
        let spec = SigSpec::new(2, 3).unwrap();
        let mut rng = Rng::new(63);
        let (batch, stream) = (6, 8);
        let paths = random_batch(&mut rng, batch, stream, 2);
        let plen = stream * 2;
        for basis in [LogSigBasis::Expanded, LogSigBasis::Lyndon, LogSigBasis::Words] {
            let plan = LogSigPlan::new(&spec, basis).unwrap();
            let dim = plan.dim();
            let g = rng.normal_vec(batch * dim, 1.0);
            let out =
                logsignature_batch_vjp(&paths, batch, stream, &spec, &plan, &g, 3).unwrap();
            for b in 0..batch {
                let single = logsignature_vjp_with(
                    &paths[b * plen..(b + 1) * plen],
                    stream,
                    &spec,
                    &plan,
                    &SigConfig::serial(),
                    &g[b * dim..(b + 1) * dim],
                )
                .unwrap();
                assert_eq!(&out[b * plen..(b + 1) * plen], single.as_slice(), "{basis:?} sample {b}");
            }
        }
    }

    #[test]
    fn batch_vjp_is_bitwise_beyond_the_mono_window() {
        // The widened planner hands logsig the d > 8 LaneFused backward
        // too; the lane engine must stay bitwise against the serial
        // scalar VJP (which dispatches the runtime-d body at d = 9).
        let spec = SigSpec::new(9, 2).unwrap();
        let plan = LogSigPlan::new(&spec, LogSigBasis::Words).unwrap();
        let mut rng = Rng::new(65);
        let (batch, stream) = (5, 4);
        let paths = random_batch(&mut rng, batch, stream, 9);
        let plen = stream * 9;
        let dim = plan.dim();
        let g = rng.normal_vec(batch * dim, 1.0);
        let out = logsignature_batch_vjp(&paths, batch, stream, &spec, &plan, &g, 2).unwrap();
        for b in 0..batch {
            let single = logsignature_vjp_with(
                &paths[b * plen..(b + 1) * plen],
                stream,
                &spec,
                &plan,
                &SigConfig::serial(),
                &g[b * dim..(b + 1) * dim],
            )
            .unwrap();
            assert_eq!(&out[b * plen..(b + 1) * plen], single.as_slice(), "sample {b}");
        }
    }

    #[test]
    fn batch_vjp_surplus_threads_match_serial_to_rounding() {
        // threads > batch routes surplus threads into each sample's stream
        // (chunked Chen identity): same values to f32 rounding.
        let spec = SigSpec::new(2, 3).unwrap();
        let plan = LogSigPlan::new(&spec, LogSigBasis::Words).unwrap();
        let mut rng = Rng::new(64);
        let (batch, stream) = (2, 80);
        let paths = random_batch(&mut rng, batch, stream, 2);
        let plen = stream * 2;
        let dim = plan.dim();
        let g = rng.normal_vec(batch * dim, 1.0);
        let out = logsignature_batch_vjp(&paths, batch, stream, &spec, &plan, &g, 8).unwrap();
        for b in 0..batch {
            let single = logsignature_vjp_with(
                &paths[b * plen..(b + 1) * plen],
                stream,
                &spec,
                &plan,
                &SigConfig::serial(),
                &g[b * dim..(b + 1) * dim],
            )
            .unwrap();
            assert_close(&out[b * plen..(b + 1) * plen], &single, 2e-3, 1e-4);
        }
    }

    #[test]
    fn batch_entry_points_error_on_bad_shapes() {
        let spec = SigSpec::new(2, 3).unwrap();
        let plan = LogSigPlan::new(&spec, LogSigBasis::Words).unwrap();
        let wrong = LogSigPlan::new(&SigSpec::new(3, 3).unwrap(), LogSigBasis::Words).unwrap();
        let paths = vec![0.0f32; 2 * 4 * 2];
        // Mismatched plan, malformed buffers, empty batch, short streams,
        // and wrong cotangent widths are all Err, never panics.
        assert!(logsignature_batch(&paths, 2, 4, &spec, &wrong, 1).is_err());
        assert!(logsignature_batch(&paths[..3], 2, 4, &spec, &plan, 1).is_err());
        assert!(logsignature_batch(&paths, 0, 4, &spec, &plan, 1).is_err());
        assert!(logsignature_batch(&paths[..4], 2, 1, &spec, &plan, 1).is_err());
        let g_ok = vec![0.0f32; 2 * plan.dim()];
        let g_bad = vec![0.0f32; 2 * plan.dim() - 1];
        assert!(logsignature_batch_vjp(&paths, 2, 4, &spec, &plan, &g_bad, 1).is_err());
        assert!(logsignature_batch_vjp(&paths, 2, 4, &spec, &wrong, &g_ok, 1).is_err());
        assert!(logsignature_batch_vjp(&paths[..3], 2, 4, &spec, &plan, &g_ok, 1).is_err());
    }
}
