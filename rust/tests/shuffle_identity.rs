//! The shuffle-product identity — the deepest algebraic invariant of the
//! signature and a strong end-to-end correctness check of the whole
//! engine.
//!
//! For any path x and words u, v with |u| + |v| ≤ N,
//!
//! ```text
//! ⟨Sig(x), u⟩ · ⟨Sig(x), v⟩ = Σ_{w ∈ u ⧢ v} ⟨Sig(x), w⟩
//! ```
//!
//! where `u ⧢ v` is the shuffle product (all interleavings, with
//! multiplicity). This characterises group-like elements of the tensor
//! algebra; a signature implementation with any systematic error in the
//! iterated-integral structure fails it immediately.

use std::collections::BTreeMap;

use signax::signature::signature;
use signax::substrate::propcheck::property;
use signax::substrate::rng::Rng;
use signax::ta::SigSpec;
use signax::words::word_index;

/// Shuffle product of two words as a multiset of words.
fn shuffle(u: &[u8], v: &[u8]) -> BTreeMap<Vec<u8>, u64> {
    let mut out = BTreeMap::new();
    if u.is_empty() {
        out.insert(v.to_vec(), 1);
        return out;
    }
    if v.is_empty() {
        out.insert(u.to_vec(), 1);
        return out;
    }
    // u ⧢ v = u1·(u' ⧢ v) + v1·(u ⧢ v').
    for (head, rest_u, rest_v) in [(u[0], &u[1..], v), (v[0], u, &v[1..])] {
        for (w, m) in shuffle(rest_u, rest_v) {
            let mut word = vec![head];
            word.extend(w);
            *out.entry(word).or_insert(0) += m;
        }
    }
    out
}

fn coeff(sig: &[f32], spec: &SigSpec, word: &[u8]) -> f64 {
    let k = word.len();
    spec.level(sig, k)[word_index(word, spec.d())] as f64
}

fn random_path(rng: &mut Rng, stream: usize, d: usize) -> Vec<f32> {
    let mut p = vec![0.0f32; stream * d];
    for i in 1..stream {
        for c in 0..d {
            p[i * d + c] = p[(i - 1) * d + c] + rng.normal_f32() * 0.3;
        }
    }
    p
}

fn random_word(rng: &mut Rng, d: usize, len: usize) -> Vec<u8> {
    (0..len).map(|_| rng.below(d) as u8).collect()
}

#[test]
fn shuffle_multiset_counts() {
    // |u ⧢ v| = C(|u|+|v|, |u|) counting multiplicity.
    let s = shuffle(&[0, 1], &[2]);
    let total: u64 = s.values().sum();
    assert_eq!(total, 3);
    // ab ⧢ ab contains aabb twice... check a simple multiplicity case:
    let s = shuffle(&[0], &[0]);
    assert_eq!(s.get(&vec![0, 0]).copied(), Some(2));
}

#[test]
fn signature_satisfies_shuffle_identity() {
    property("shuffle identity", 40, |g| {
        let d = g.usize_in(2, 4);
        let lu = g.usize_in(1, 2);
        let lv = g.usize_in(1, 3);
        let n = lu + lv; // need |u|+|v| <= depth
        let stream = g.usize_in(2, 10);
        let spec = SigSpec::new(d, n).unwrap();
        let path = random_path(g.rng(), stream, d);
        let sig = signature(&path, stream, &spec);
        let u = random_word(g.rng(), d, lu);
        let v = random_word(g.rng(), d, lv);
        g.label(format!("d={d} n={n} stream={stream} u={u:?} v={v:?}"));

        let lhs = coeff(&sig, &spec, &u) * coeff(&sig, &spec, &v);
        let rhs: f64 = shuffle(&u, &v)
            .iter()
            .map(|(w, &m)| m as f64 * coeff(&sig, &spec, w))
            .sum();
        let scale = 1.0 + lhs.abs().max(rhs.abs());
        assert!(
            (lhs - rhs).abs() < 1e-3 * scale,
            "shuffle identity violated: lhs={lhs} rhs={rhs}"
        );
    });
}

#[test]
fn shuffle_identity_holds_for_xla_artifact_output() {
    // End-to-end: the AOT-compiled Pallas/JAX signature also satisfies the
    // identity (checked on the showcase artifact when present).
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("MANIFEST.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let (engine, registry) = signax::runtime::EngineHandle::spawn(dir).unwrap();
    let Some(entry) = registry.find(signax::runtime::ArtifactKind::Sig, 1, 128, 4, 4).cloned()
    else {
        return;
    };
    let spec = SigSpec::new(4, 4).unwrap();
    let mut rng = Rng::new(17);
    let path = random_path(&mut rng, 128, 4);
    let sig = engine.forward(&entry, path).unwrap();
    for _ in 0..20 {
        let u = random_word(&mut rng, 4, 2);
        let v = random_word(&mut rng, 4, 2);
        let lhs = coeff(&sig, &spec, &u) * coeff(&sig, &spec, &v);
        let rhs: f64 = shuffle(&u, &v)
            .iter()
            .map(|(w, &m)| m as f64 * coeff(&sig, &spec, w))
            .sum();
        let scale = 1.0 + lhs.abs().max(rhs.abs());
        assert!((lhs - rhs).abs() < 5e-3 * scale, "u={u:?} v={v:?}: {lhs} vs {rhs}");
    }
}
