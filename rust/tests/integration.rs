//! Integration tests across the full stack: golden files pin the native
//! engine to the Python oracle; the PJRT runtime executes real artifacts
//! and is pinned to the native engine; the coordinator routes between
//! them. Tests skip gracefully (with a message) when `make artifacts` has
//! not been run.

use std::path::PathBuf;

use signax::coordinator::{Backend, Coordinator, CoordinatorConfig, Request};
use signax::data::gbm::{gbm_batch, GbmConfig};
use signax::deepsig::{ModelConfig, Params};
use signax::logsignature::{logsignature_from_sig, LogSigBasis, LogSigPlan};
use signax::runtime::{ArtifactKind, EngineHandle, Registry};
use signax::signature::{signature, signature_batch, signature_stream, signature_vjp};
use signax::substrate::json::Json;
use signax::substrate::propcheck::assert_close;
use signax::substrate::rng::Rng;
use signax::ta::SigSpec;

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("MANIFEST.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn golden_files_pin_native_engine_to_python_oracle() {
    let Some(dir) = artifact_dir() else { return };
    let golden = dir.join("golden");
    let mut checked = 0;
    for entry in std::fs::read_dir(&golden).expect("golden dir") {
        let path = entry.expect("entry").path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let blob = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let d = blob.get("d").unwrap().as_usize().unwrap();
        let depth = blob.get("depth").unwrap().as_usize().unwrap();
        let length = blob.get("length").unwrap().as_usize().unwrap();
        let pathbuf = blob.get("path").unwrap().as_f32_vec().unwrap();
        let spec = SigSpec::new(d, depth).unwrap();

        // Signature.
        let sig = signature(&pathbuf, length, &spec);
        let expect_sig = blob.get("sig").unwrap().as_f32_vec().unwrap();
        assert_close(&sig, &expect_sig, 2e-4, 1e-5);

        // Words-basis logsignature.
        let plan = LogSigPlan::new(&spec, LogSigBasis::Words).unwrap();
        let logsig = logsignature_from_sig(&sig, &spec, &plan).unwrap();
        let expect_log = blob.get("logsig_words").unwrap().as_f32_vec().unwrap();
        assert_close(&logsig, &expect_log, 5e-4, 5e-5);

        // Gradient of sum(sig) — pins the reversibility backward to
        // jax.grad through the oracle.
        let ones = vec![1.0f32; spec.sig_len()];
        let grad = signature_vjp(&pathbuf, length, &spec, &ones);
        let expect_grad = blob.get("grad_sum_sig").unwrap().as_f32_vec().unwrap();
        assert_close(&grad, &expect_grad, 2e-3, 2e-4);

        // Final two stream entries.
        let stream = signature_stream(&pathbuf, length, &spec);
        let expect_tail = blob.get("stream_last2").unwrap().as_f32_vec().unwrap();
        let tail = &stream[(length - 3) * spec.sig_len()..];
        assert_close(tail, &expect_tail, 2e-4, 2e-5);
        checked += 1;
    }
    assert!(checked >= 5, "expected at least 5 golden files, saw {checked}");
}

#[test]
fn streaming_sessions_end_to_end_native() {
    // Needs no artifacts: the streaming surface is always served natively.
    let spec = SigSpec::new(3, 3).unwrap();
    let coord = Coordinator::new(CoordinatorConfig::native_only()).expect("coordinator");
    let mut rng = Rng::new(77);
    let all: Vec<f32> = {
        // A continuous path so interval queries are well-conditioned.
        let mut p = vec![0.0f32; 40 * 3];
        for i in 1..40 {
            for c in 0..3 {
                p[i * 3 + c] = p[(i - 1) * 3 + c] + rng.normal_f32() * 0.2;
            }
        }
        p
    };
    let open = coord
        .call(Request::OpenStream {
            points: all[..10 * 3].to_vec().into(),
            stream: 10,
            d: 3,
            depth: 3,
        })
        .unwrap();
    let sid = open.session.expect("session id");
    assert_eq!(open.backend, Backend::Native);
    // Feed the rest in three chunks; the final signature must match the
    // one-shot computation over the whole path.
    let mut last = open.values;
    for chunk in all[10 * 3..].chunks(10 * 3) {
        let resp = coord
            .call(Request::Feed {
                session: sid,
                points: chunk.to_vec().into(),
                count: chunk.len() / 3,
            })
            .unwrap();
        last = resp.values;
    }
    assert_close(last.as_f32().unwrap(), &signature(&all, 40, &spec), 5e-3, 5e-4);
    // Interval query spanning feed boundaries matches recomputation.
    let q = coord.call(Request::QueryInterval { session: sid, i: 7, j: 33 }).unwrap();
    assert_close(q.values.as_f32().unwrap(), &signature(&all[7 * 3..34 * 3], 27, &spec), 1e-2, 1e-3);
    // Logsig interval query has the words-basis dimension.
    let lq = coord.call(Request::LogSigQueryInterval { session: sid, i: 7, j: 33 }).unwrap();
    assert_eq!(lq.values.len(), signax::words::witt_dimension(3, 3));
    // Metrics cover the streaming surface; close releases the storage.
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.stream_requests, snap.requests);
    assert_eq!(snap.open_sessions, 1);
    assert!(snap.session_bytes > 0);
    coord.call(Request::CloseStream { session: sid }).unwrap();
    assert!(coord
        .call(Request::Feed { session: sid, points: vec![0.0f32; 3].into(), count: 1 })
        .is_err());
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.open_sessions, 0);
    assert_eq!(snap.session_bytes, 0);
    assert_eq!(snap.errors, 1);
}

#[test]
fn xla_sig_artifact_matches_native_engine() {
    let Some(dir) = artifact_dir() else { return };
    let (engine, registry) = EngineHandle::spawn(dir).expect("engine");
    let entry = registry
        .find(ArtifactKind::Sig, 32, 128, 4, 4)
        .expect("pallas showcase artifact")
        .clone();
    assert!(entry.pallas, "showcase artifact should embed the Pallas kernel");
    let spec = SigSpec::new(4, 4).unwrap();
    let mut rng = Rng::new(99);
    let paths = signax::data::random_batch(&mut rng, 32, 128, 4, 0.1);
    let xla_out = engine.forward(&entry, paths.clone()).expect("xla run");
    let native = signature_batch(&paths, 32, 128, &spec, 4).unwrap();
    assert_close(&xla_out, &native, 5e-3, 5e-4);
}

#[test]
fn xla_logsig_artifact_matches_native_engine() {
    let Some(dir) = artifact_dir() else { return };
    let (engine, registry) = EngineHandle::spawn(dir).expect("engine");
    let entry = registry
        .find(ArtifactKind::LogSig, 32, 128, 4, 4)
        .expect("logsig artifact")
        .clone();
    let spec = SigSpec::new(4, 4).unwrap();
    let plan = LogSigPlan::new(&spec, LogSigBasis::Words).unwrap();
    let mut rng = Rng::new(7);
    let paths = signax::data::random_batch(&mut rng, 32, 128, 4, 0.1);
    let xla_out = engine.forward(&entry, paths.clone()).expect("xla run");
    for b in 0..4 {
        let one = &paths[b * 128 * 4..(b + 1) * 128 * 4];
        let sig = signature(one, 128, &spec);
        let native = logsignature_from_sig(&sig, &spec, &plan).unwrap();
        assert_close(
            &xla_out[b * plan.dim()..(b + 1) * plan.dim()],
            &native,
            1e-2,
            1e-3,
        );
    }
}

#[test]
fn xla_siggrad_artifact_matches_reversibility_backward() {
    let Some(dir) = artifact_dir() else { return };
    let (engine, registry) = EngineHandle::spawn(dir).expect("engine");
    let Some(entry) = registry.find(ArtifactKind::SigGrad, 1, 128, 4, 4).cloned() else {
        eprintln!("skipping: no siggrad artifact (sweep=none?)");
        return;
    };
    let spec = SigSpec::new(4, 4).unwrap();
    let mut rng = Rng::new(13);
    let path = signax::data::random_path(&mut rng, 128, 4, 0.1);
    let cot = rng.normal_vec(spec.sig_len(), 1.0);
    let xla_grad = engine.grad(&entry, path.clone(), cot.clone()).expect("xla grad");
    let native = signature_vjp(&path, 128, &spec, &cot);
    assert_close(&xla_grad, &native, 1e-2, 1e-3);
}

#[test]
fn coordinator_routes_matching_requests_to_xla() {
    let Some(dir) = artifact_dir() else { return };
    let coord = Coordinator::new(CoordinatorConfig {
        artifact_dir: Some(dir),
        ..Default::default()
    })
    .expect("coordinator");
    assert!(coord.has_xla());
    let mut rng = Rng::new(5);
    let spec = SigSpec::new(4, 4).unwrap();

    // Matching shape -> XLA (through the batcher).
    let path = signax::data::random_path(&mut rng, 128, 4, 0.1);
    let resp = coord
        .call(Request::Signature { path: path.clone().into(), stream: 128, d: 4, depth: 4 })
        .unwrap();
    assert_eq!(resp.backend, Backend::Xla);
    assert_close(resp.values.as_f32().unwrap(), &signature(&path, 128, &spec), 5e-3, 5e-4);

    // Non-matching shape -> native fallback.
    let short = signax::data::random_path(&mut rng, 16, 4, 0.1);
    let resp = coord
        .call(Request::Signature { path: short.clone().into(), stream: 16, d: 4, depth: 4 })
        .unwrap();
    assert_eq!(resp.backend, Backend::Native);

    let snap = coord.metrics().snapshot();
    assert_eq!(snap.xla_requests, 1);
    assert_eq!(snap.native_requests, 1);
}

#[test]
fn coordinator_batches_concurrent_requests() {
    let Some(dir) = artifact_dir() else { return };
    let coord = Coordinator::new(CoordinatorConfig {
        artifact_dir: Some(dir),
        ..Default::default()
    })
    .expect("coordinator");
    let mut rng = Rng::new(21);
    let spec = SigSpec::new(4, 4).unwrap();
    let paths: Vec<Vec<f32>> =
        (0..8).map(|_| signax::data::random_path(&mut rng, 128, 4, 0.1)).collect();
    let reqs: Vec<Request> = paths
        .iter()
        .map(|p| Request::Signature { path: p.clone().into(), stream: 128, d: 4, depth: 4 })
        .collect();
    let resps = coord.call_many(reqs);
    for (p, r) in paths.iter().zip(resps) {
        let r = r.expect("response");
        assert_eq!(r.backend, Backend::Xla);
        assert_close(r.values.as_f32().unwrap(), &signature(p, 128, &spec), 5e-3, 5e-4);
    }
    let snap = coord.metrics().snapshot();
    // 8 requests coalesced into at most a few padded batches of 32.
    assert!(snap.batches <= 3, "batches={}", snap.batches);
    assert_eq!(snap.real_rows, 8);
}

#[test]
fn xla_train_step_learns_and_matches_native_training() {
    let Some(dir) = artifact_dir() else { return };
    let (engine, registry) = EngineHandle::spawn(dir).expect("engine");
    let entry = registry.train().expect("train artifact").clone();
    let cfg = ModelConfig {
        d_in: entry.d,
        hidden: entry.hidden,
        d_out: entry.d_out,
        depth: entry.depth,
        logsig: false,
    };
    let mut rng = Rng::new(1234);
    let p0 = Params::init(&cfg, &mut rng);
    let gcfg = GbmConfig { stream: entry.length, ..Default::default() };
    let (x, y) = gbm_batch(&mut rng, entry.batch, &gcfg);

    // A few XLA steps: loss must be finite and decrease overall.
    let mut bufs = p0.to_buffers();
    let mut first = None;
    let mut last = 0.0f32;
    for _ in 0..20 {
        let (new_bufs, loss) =
            engine.train_step(&entry, bufs, x.clone(), y.clone(), 0.5).expect("train step");
        bufs = new_bufs;
        assert!(loss.is_finite());
        first.get_or_insert(loss);
        last = loss;
    }
    assert!(
        last < first.unwrap(),
        "XLA training did not reduce loss: {first:?} -> {last}"
    );

    // One step from identical params must match the native trainer closely
    // (same math, both f32).
    let mut native_p = p0.clone();
    let native_loss = signax::deepsig::train_step(
        &cfg,
        &mut native_p,
        &x,
        &y,
        0.5,
        signax::deepsig::SigBackend::Fused,
        4,
    );
    let (xla_bufs, xla_loss) =
        engine.train_step(&entry, p0.to_buffers(), x.clone(), y.clone(), 0.5).expect("step");
    assert!(
        (native_loss - xla_loss).abs() < 5e-3 * (1.0 + native_loss.abs()),
        "losses diverge: native {native_loss} vs xla {xla_loss}"
    );
    let xla_p = Params::from_buffers(&cfg, &xla_bufs);
    assert_close(&xla_p.w_out, &native_p.w_out, 5e-2, 5e-3);
}

#[test]
fn manifest_registry_consistent_with_disk() {
    let Some(dir) = artifact_dir() else { return };
    let registry = Registry::load(&dir).expect("registry");
    assert!(!registry.entries.is_empty());
    for e in &registry.entries {
        let p = registry.path_of(e);
        assert!(p.exists(), "missing artifact file {p:?}");
        let head = std::fs::read_to_string(&p).unwrap();
        assert!(head.starts_with("HloModule"), "{p:?} is not HLO text");
    }
}

#[test]
fn coordinator_warm_restart_answers_queries_bitwise() {
    // The PR 7 acceptance path end to end: open sessions through the
    // coordinator front door against a disk state dir, feed them, tear
    // the coordinator down (the process "dies"), bring a fresh one up on
    // the same dir, and every session must answer QueryInterval bitwise
    // identically to an unrestarted control coordinator that served the
    // same traffic.
    use signax::coordinator::{SessionConfig, SessionId};
    use signax::state::SpillConfig;

    let dir = std::env::temp_dir()
        .join(format!("signax-it-restart-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = || CoordinatorConfig {
        session: SessionConfig {
            spill: SpillConfig::Disk(dir.clone()),
            ..SessionConfig::default()
        },
        ..CoordinatorConfig::native_only()
    };
    let control = Coordinator::new(CoordinatorConfig::native_only()).unwrap();
    let mut rng = Rng::new(0xACC7);
    let n = 5usize;
    let mut sessions: Vec<(SessionId, SessionId)> = vec![];
    {
        let coord = Coordinator::new(cfg()).unwrap();
        for k in 0..n {
            let d = 2 + k % 2;
            let seed = rng.normal_vec(6 * d, 0.4);
            let open = |c: &Coordinator| {
                c.call(Request::OpenStream {
                    points: seed.clone().into(),
                    stream: 6,
                    d,
                    depth: 3,
                })
                .unwrap()
                .session
                .unwrap()
            };
            let (id, cid) = (open(&coord), open(&control));
            let extra = rng.normal_vec(4 * d, 0.4);
            for (c, s) in [(&coord, id), (&control, cid)] {
                c.call(Request::Feed { session: s, points: extra.clone().into(), count: 4 })
                    .unwrap();
            }
            sessions.push((id, cid));
        }
        // Coordinator drops here: sweeper joins, feed log flushes.
    }
    let revived = Coordinator::new(cfg()).unwrap();
    for &(id, cid) in &sessions {
        for (i, j) in [(0usize, 9usize), (2, 7), (4, 9)] {
            let got = revived.call(Request::QueryInterval { session: id, i, j }).unwrap();
            let want = control.call(Request::QueryInterval { session: cid, i, j }).unwrap();
            assert_eq!(got.values, want.values, "restart diverged at interval ({i}, {j})");
        }
        let got = revived.call(Request::LogSigQueryInterval { session: id, i: 1, j: 8 }).unwrap();
        let want = control.call(Request::LogSigQueryInterval { session: cid, i: 1, j: 8 }).unwrap();
        assert_eq!(got.values, want.values, "logsig query diverged after restart");
    }
    // Post-restart feeds keep agreeing bitwise (the recovered Path is the
    // same resumable state, not a lookalike).
    let (id0, cid0) = sessions[0];
    let more = rng.normal_vec(3 * 2, 0.4);
    let got = revived
        .call(Request::Feed { session: id0, points: more.clone().into(), count: 3 })
        .unwrap();
    let want =
        control.call(Request::Feed { session: cid0, points: more.into(), count: 3 }).unwrap();
    assert_eq!(got.values, want.values, "post-restart feed diverged");
    let _ = std::fs::remove_dir_all(&dir);
}
