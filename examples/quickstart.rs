//! Quickstart: compute signatures and logsignatures with the native
//! engine, mirroring the paper's §3 code example.
//!
//!     cargo run --release --example quickstart

use signax::logsignature::{logsignature_with, LogSigBasis, LogSigPlan};
use signax::signature::{signature, signature_stream, signature_vjp, SigConfig};
use signax::substrate::rng::Rng;
use signax::ta::SigSpec;
use signax::words::witt_dimension;

fn main() -> anyhow::Result<()> {
    // The paper's example: batch=1, stream=10, channels=2, depth=4.
    let (stream, channels, depth) = (10usize, 2usize, 4usize);
    let spec = SigSpec::new(channels, depth)?;

    // A random path, shape (stream, channels) flattened row-major.
    let mut rng = Rng::new(0);
    let path = signax::data::random_path(&mut rng, stream, channels, 0.5);

    // signature = signatory.signature(path, depth)
    let sig = signature(&path, stream, &spec);
    println!("signature: {} values (d + d² + ... + d^N = {})", sig.len(), spec.sig_len());
    println!("  level 1 = total increment: {:?}", &sig[..channels]);

    // signature.sum().backward() — the handwritten backward pass.
    let ones = vec![1.0f32; spec.sig_len()];
    let grad = signature_vjp(&path, stream, &spec, &ones);
    println!("  d(sum sig)/d(path) has shape ({stream}, {channels}); first point: {:?}", &grad[..channels]);

    // Logsignature in the paper's efficient Words basis (§4.3).
    let plan = LogSigPlan::new(&spec, LogSigBasis::Words)?;
    let logsig = logsignature_with(&path, stream, &spec, &plan, &SigConfig::serial())?;
    println!(
        "logsignature: {} values (Witt dimension w({channels},{depth}) = {})",
        logsig.len(),
        witt_dimension(channels, depth)
    );

    // Stream mode: every prefix signature in one O(L) sweep (§5.5).
    let st = signature_stream(&path, stream, &spec);
    println!("stream mode: {} prefix signatures of {} values each", stream - 1, spec.sig_len());
    let last = &st[(stream - 2) * spec.sig_len()..];
    assert!(last.iter().zip(&sig).all(|(a, b)| (a - b).abs() < 1e-6));
    println!("  last prefix equals the full signature ✓");
    Ok(())
}
