//! Domain example from the paper's motivation (§1: "handwriting
//! identification ... signatures as feature transformations").
//!
//! Synthetic pen strokes from two writers (differing curvature/jitter
//! style) are summarised by **windowed logsignature features** — computed
//! with O(1) interval queries against a precomputed [`signax::path::Path`]
//! — and classified by a tiny perceptron trained on those features. This
//! is the "feature transformation" usage mode of the signature (as opposed
//! to the in-network usage of `deep_signature_training.rs`).
//!
//!     cargo run --release --example handwriting_features

use signax::logsignature::{LogSigBasis, LogSigPlan, LogSigWorkspace};
use signax::path::Path;
use signax::substrate::rng::Rng;
use signax::ta::SigSpec;

/// A synthetic pen stroke: a noisy spiral whose turn rate and jitter are
/// writer-specific. Returns (stream, 2) points.
fn stroke(rng: &mut Rng, writer: usize, len: usize) -> Vec<f32> {
    let (turn, jitter) = if writer == 0 { (0.15f32, 0.02f32) } else { (0.28, 0.06) };
    let mut p = vec![0.0f32; len * 2];
    let mut theta = rng.uniform_in(0.0, std::f32::consts::TAU);
    let (mut x, mut y) = (0.0f32, 0.0f32);
    for i in 1..len {
        theta += turn + rng.normal_f32() * jitter;
        x += theta.cos() * 0.1;
        y += theta.sin() * 0.1;
        p[i * 2] = x;
        p[i * 2 + 1] = y;
    }
    p
}

/// Windowed logsignature features over `windows` dyadic sub-intervals.
/// One `LogSigWorkspace` is threaded through every query (and reused
/// across all 400 strokes by the caller), so the feature extraction loop
/// — the hot path of this example — allocates nothing per window beyond
/// the output buffer itself.
fn features(
    path: &Path,
    plan: &LogSigPlan,
    windows: usize,
    ws: &mut LogSigWorkspace,
) -> anyhow::Result<Vec<f32>> {
    let n = path.len();
    let dim = plan.dim();
    let mut out = vec![0.0f32; (windows + 1) * dim];
    // Whole-stroke logsignature plus per-window logsignatures, all O(1)
    // queries against the precomputation (§4.2), allocation-free via
    // `Path::logsig_query_into`.
    path.logsig_query_into(0, n - 1, plan, ws, &mut out[..dim])?;
    for w in 0..windows {
        let i = w * (n - 1) / windows;
        let j = (w + 1) * (n - 1) / windows;
        path.logsig_query_into(
            i,
            j.max(i + 1),
            plan,
            ws,
            &mut out[(w + 1) * dim..(w + 2) * dim],
        )?;
    }
    Ok(out)
}

fn main() -> anyhow::Result<()> {
    let spec = SigSpec::new(2, 4)?;
    let plan = LogSigPlan::new(&spec, LogSigBasis::Words)?;
    let (len, windows) = (128usize, 4usize);
    let feat_dim = (windows + 1) * plan.dim();
    let mut rng = Rng::new(99);

    // Dataset: 200 strokes per writer. One logsig workspace serves every
    // query of every stroke.
    let mut ws = LogSigWorkspace::new(&spec);
    let mut xs: Vec<Vec<f32>> = vec![];
    let mut ys: Vec<f32> = vec![];
    for _ in 0..400 {
        let writer = (rng.next_u64() & 1) as usize;
        let s = stroke(&mut rng, writer, len);
        let p = Path::new(&spec, &s, len)?;
        xs.push(features(&p, &plan, windows, &mut ws)?);
        ys.push(writer as f32);
    }
    println!(
        "400 strokes -> {} windowed logsignature features each (w(2,4)={} per window)",
        feat_dim,
        plan.dim()
    );

    // Normalise features, then train a perceptron with plain SGD.
    let mut mean = vec![0.0f32; feat_dim];
    let mut var = vec![0.0f32; feat_dim];
    for x in &xs {
        for (m, &v) in mean.iter_mut().zip(x) {
            *m += v / xs.len() as f32;
        }
    }
    for x in &xs {
        for ((s, &m), &v) in var.iter_mut().zip(&mean).zip(x) {
            *s += (v - m) * (v - m) / xs.len() as f32;
        }
    }
    let xs: Vec<Vec<f32>> = xs
        .iter()
        .map(|x| {
            x.iter()
                .zip(&mean)
                .zip(&var)
                .map(|((&v, &m), &s)| (v - m) / (s.sqrt() + 1e-6))
                .collect()
        })
        .collect();

    let (train_n, test_n) = (300usize, 100usize);
    let mut w = vec![0.0f32; feat_dim];
    let mut b = 0.0f32;
    for epoch in 0..40 {
        let mut loss_sum = 0.0f32;
        for i in 0..train_n {
            let logit: f32 = xs[i].iter().zip(&w).map(|(&x, &wv)| x * wv).sum::<f32>() + b;
            let y = ys[i];
            loss_sum += logit.max(0.0) - logit * y + (-logit.abs()).exp().ln_1p();
            let dl = 1.0 / (1.0 + (-logit).exp()) - y;
            for (wv, &x) in w.iter_mut().zip(&xs[i]) {
                *wv -= 0.05 * dl * x;
            }
            b -= 0.05 * dl;
        }
        if epoch % 10 == 0 {
            println!("epoch {epoch}: train loss {:.4}", loss_sum / train_n as f32);
        }
    }
    let mut correct = 0usize;
    for i in train_n..train_n + test_n {
        let logit: f32 = xs[i].iter().zip(&w).map(|(&x, &wv)| x * wv).sum::<f32>() + b;
        if (logit > 0.0) == (ys[i] > 0.5) {
            correct += 1;
        }
    }
    let acc = correct as f32 / test_n as f32;
    println!("writer identification test accuracy: {acc:.3} (chance 0.5)");
    anyhow::ensure!(acc > 0.8, "features should separate the writers");
    Ok(())
}
