//! The Path class (§4.2): O(L) precomputation, O(1) arbitrary-interval
//! signature queries — the paper's improvement over the O(log L) scheme of
//! Chafai & Lyons (2005).
//!
//!     cargo run --release --example interval_queries

use std::time::Instant;

use signax::logsignature::{LogSigBasis, LogSigPlan};
use signax::path::Path;
use signax::substrate::rng::Rng;
use signax::ta::SigSpec;

fn main() -> anyhow::Result<()> {
    let spec = SigSpec::new(4, 4)?;
    let stream = 4096usize;
    let mut rng = Rng::new(42);
    let pts = signax::data::random_path(&mut rng, stream, 4, 0.1);

    let t0 = Instant::now();
    let path = Path::new(&spec, &pts, stream)?;
    println!(
        "precomputed {} expanding + inverted signatures in {:.1}ms ({} KiB stored)",
        stream - 1,
        t0.elapsed().as_secs_f64() * 1e3,
        path.storage_bytes() / 1024
    );

    // Query many random intervals two ways.
    let queries: Vec<(usize, usize)> = (0..1000)
        .map(|_| {
            let i = rng.below(stream - 1);
            let j = rng.in_range(i + 1, stream - 1);
            (i, j)
        })
        .collect();

    let t0 = Instant::now();
    let mut acc = 0.0f32;
    for &(i, j) in &queries {
        acc += path.query(i, j)?[0];
    }
    let fast = t0.elapsed();

    let t0 = Instant::now();
    let mut acc2 = 0.0f32;
    for &(i, j) in &queries {
        acc2 += path.query_recompute(i, j)?[0];
    }
    let slow = t0.elapsed();
    println!(
        "1000 interval queries: O(1) precomputed {:.1}ms vs recompute {:.1}ms ({:.0}x)",
        fast.as_secs_f64() * 1e3,
        slow.as_secs_f64() * 1e3,
        slow.as_secs_f64() / fast.as_secs_f64()
    );
    assert!((acc - acc2).abs() < 1.0, "query paths disagree: {acc} vs {acc2}");

    // Logsignature queries work too (§4.2's "followed by a log").
    let plan = LogSigPlan::new(&spec, LogSigBasis::Words)?;
    let z = path.logsig_query(100, 2000, &plan)?;
    println!("logsig over [100, 2000]: {} Words-basis coefficients", z.len());

    // Streaming update: new data arrives, the precomputation extends in
    // O(new points) (§5.5 "keeping the signature up-to-date").
    let mut path = path;
    let extra = signax::data::random_path(&mut rng, 512, 4, 0.1);
    let t0 = Instant::now();
    path.update(&extra, 512)?;
    println!(
        "appended 512 points in {:.2}ms; intervals across the seam still O(1): {:?}...",
        t0.elapsed().as_secs_f64() * 1e3,
        &path.query(stream - 3, stream + 100)?[..2]
    );
    Ok(())
}
