//! Streaming sessions through the coordinator: "keeping the signature
//! up-to-date" (§5.5, eq. 7) as a serving primitive — e.g. maintaining
//! running signatures of live financial tick data.
//!
//!     cargo run --release --example streaming_updates

use signax::coordinator::{Coordinator, CoordinatorConfig};
use signax::data::gbm::{gbm_batch, GbmConfig};
use signax::signature::signature;
use signax::substrate::rng::Rng;
use signax::ta::SigSpec;

fn main() -> anyhow::Result<()> {
    let spec = SigSpec::new(2, 4)?;
    let coord = Coordinator::new(CoordinatorConfig::native_only())?;
    let sessions = coord.sessions();

    // Open 8 sessions fed by independent GBM tick streams.
    let mut rng = Rng::new(1);
    let gcfg = GbmConfig { stream: 16, ..Default::default() };
    let mut ids = vec![];
    let mut full_paths: Vec<Vec<f32>> = vec![];
    for _ in 0..8 {
        let (x, _) = gbm_batch(&mut rng, 1, &gcfg);
        let id = sessions.open(&spec, &x, 16)?;
        ids.push(id);
        full_paths.push(x);
    }
    println!("opened {} streaming sessions", ids.len());

    // Ticks arrive in chunks; each feed returns the up-to-date signature
    // over the whole stream so far, costing only O(chunk) fused steps.
    for round in 0..5 {
        for (s, id) in ids.iter().enumerate() {
            let (chunk, _) = gbm_batch(&mut rng, 1, &GbmConfig { stream: 8, ..Default::default() });
            let sig = sessions.feed(*id, &chunk, 8)?;
            full_paths[s].extend_from_slice(&chunk);
            if s == 0 {
                println!(
                    "round {round}: session 0 now {} points, sig[0..3] = {:?}",
                    sessions.session_len(*id)?,
                    &sig[..3]
                );
            }
        }
    }

    // Verify a session's running signature against a from-scratch
    // recomputation of its whole history.
    let n = full_paths[0].len() / 2;
    let direct = signature(&full_paths[0], n, &spec);
    let via_session = sessions.query(ids[0], 0, n - 1)?;
    let max_err = direct
        .iter()
        .zip(&via_session)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("session vs from-scratch signature: max abs err {max_err:.2e}");
    assert!(max_err < 1e-2);

    // Mid-stream interval analytics on the live session (§4.2).
    let q = sessions.query(ids[0], 10, 40)?;
    println!("interval [10, 40] signature (O(1) query): {:?}...", &q[..2]);
    println!("metrics: {}", coord.metrics().snapshot().render());
    Ok(())
}
