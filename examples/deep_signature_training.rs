//! End-to-end driver (Fig 3 / §6.2): train the deep signature model on the
//! GBM volatility-classification task, comparing
//!
//! - the signax backend (fused forward + reversibility backward),
//! - the iisignature-profile backend (conventional forward + tape
//!   backward), and
//! - the AOT-XLA train-step artifact (JAX-lowered fwd+bwd+SGD executed via
//!   PJRT from Rust)
//!
//! logging loss against wall-clock time for each. This exercises every
//! layer of the stack end to end: data generation (L3), the native engine
//! with handwritten VJPs (L3), and the L2/L1-lowered artifact through the
//! runtime.
//!
//!     cargo run --release --example deep_signature_training

use std::io::Write as _;
use std::time::Instant;

use signax::data::gbm::{gbm_batch, GbmConfig};
use signax::deepsig::{accuracy, train_step, ModelConfig, Params, SigBackend};
use signax::runtime::EngineHandle;
use signax::substrate::rng::Rng;

fn main() -> anyhow::Result<()> {
    let steps = 500usize;
    let (batch, stream) = (32usize, 64usize);
    let lr = 0.3f32;
    let cfg = ModelConfig::default(); // 2 -> 16 -> 4 channels, depth 3
    let gcfg = GbmConfig { stream, ..Default::default() };
    std::fs::create_dir_all("results")?;

    // Shared, deterministic data and init so the curves are comparable:
    // one pre-generated batch per step (true SGD), identical across
    // backends.
    let mut rng = Rng::new(2024);
    let p0 = Params::init(&cfg, &mut rng);
    let batches: Vec<(Vec<f32>, Vec<f32>)> =
        (0..steps).map(|_| gbm_batch(&mut rng, batch, &gcfg)).collect();
    let (xt, yt) = gbm_batch(&mut rng, 512, &gcfg);

    let mut summaries = vec![];
    for (name, backend) in [("signax-fused", SigBackend::Fused), ("iisignature-like", SigBackend::Conventional)]
    {
        let mut p = p0.clone();
        let t0 = Instant::now();
        let mut curve = vec![];
        for (x, y) in &batches {
            let loss = train_step(&cfg, &mut p, x, y, lr, backend, signax::substrate::pool::default_threads());
            curve.push((t0.elapsed().as_secs_f64(), loss));
        }
        let wall = t0.elapsed().as_secs_f64();
        let acc = accuracy(&cfg, &p, &xt, &yt);
        println!(
            "{name:<18} {steps} steps in {wall:>7.2}s  final loss {:.4}  test acc {acc:.3}",
            curve.last().unwrap().1
        );
        write_curve(&format!("results/fig3_loss_{name}.csv"), &curve)?;
        summaries.push((name, wall, acc));
    }

    // XLA backend, when artifacts exist.
    let dir = std::path::PathBuf::from("artifacts");
    if dir.join("MANIFEST.json").exists() {
        let (engine, registry) = EngineHandle::spawn(dir)?;
        if let Some(entry) = registry.train().cloned() {
            let mut bufs = p0.to_buffers();
            engine.warm(&entry)?;
            let t0 = Instant::now();
            let mut curve = vec![];
            for (x, y) in &batches {
                let (nb, loss) = engine.train_step(&entry, bufs, x.clone(), y.clone(), lr)?;
                bufs = nb;
                curve.push((t0.elapsed().as_secs_f64(), loss));
            }
            let wall = t0.elapsed().as_secs_f64();
            let p = Params::from_buffers(&ModelConfig::default(), &bufs);
            let acc = accuracy(&cfg, &p, &xt, &yt);
            println!(
                "{:<18} {steps} steps in {wall:>7.2}s  final loss {:.4}  test acc {acc:.3}",
                "signax-xla",
                curve.last().unwrap().1
            );
            write_curve("results/fig3_loss_signax-xla.csv", &curve)?;
            summaries.push(("signax-xla", wall, acc));
        }
    } else {
        eprintln!("(skipping XLA backend: run `make artifacts`)");
    }

    // The Fig 3 headline: how much faster the fused/reversible backend
    // trains the same model to the same loss.
    if let (Some(f), Some(c)) = (
        summaries.iter().find(|s| s.0 == "signax-fused"),
        summaries.iter().find(|s| s.0 == "iisignature-like"),
    ) {
        println!(
            "\nFig 3 reproduction: signax trains {:.1}x faster than the iisignature-profile backend \
             (paper reports 210x vs CPU-bound iisignature from the GPU; like-for-like CPU ratio is the comparable number here)",
            c.1 / f.1
        );
    }
    println!("loss curves in results/fig3_loss_*.csv");

    // --- Phase 2: the signature-dominated regime. ---
    // At small (d, N) the pointwise MLP dominates and the backends tie; the
    // paper's speedups appear when the signature is the bottleneck (its
    // motivating setting, §1). Same pipeline, wider/deeper signature,
    // single-threaded (like-for-like resources, as in §6.1).
    println!("\n--- signature-dominated regime (d_out=6, depth=5, 1 thread) ---");
    let big = ModelConfig { d_in: 2, hidden: 16, d_out: 6, depth: 5, logsig: false };
    let mut rng2 = Rng::new(77);
    let pb0 = Params::init(&big, &mut rng2);
    let big_batches: Vec<(Vec<f32>, Vec<f32>)> =
        (0..30).map(|_| gbm_batch(&mut rng2, 8, &gcfg)).collect();
    let mut walls = vec![];
    for (name, backend) in
        [("signax-fused", SigBackend::Fused), ("iisignature-like", SigBackend::Conventional)]
    {
        let mut p = pb0.clone();
        let t0 = Instant::now();
        let mut last = 0.0;
        for (x, y) in &big_batches {
            last = train_step(&big, &mut p, x, y, 0.05, backend, 1);
        }
        let wall = t0.elapsed().as_secs_f64();
        println!("{name:<18} 30 steps in {wall:>7.2}s  final loss {last:.4}");
        walls.push(wall);
    }
    println!(
        "signature-dominated speedup (fused vs conventional, 1 thread): {:.1}x",
        walls[1] / walls[0]
    );
    Ok(())
}

fn write_curve(path: &str, curve: &[(f64, f32)]) -> anyhow::Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "wallclock_s,loss")?;
    for (t, l) in curve {
        writeln!(f, "{t},{l}")?;
    }
    Ok(())
}
