//! Serving pipeline demo: concurrent clients → router → dynamic batcher →
//! XLA engine (with native fallback), reporting throughput, latency and
//! padding efficiency — the coordinator as a vLLM-style serving system for
//! signature computations.
//!
//!     cargo run --release --example serving_pipeline

use std::time::Instant;

use signax::coordinator::{Backend, Coordinator, CoordinatorConfig, Request};
use signax::path::WindowSpec;
use signax::substrate::rng::Rng;

fn main() -> anyhow::Result<()> {
    let coord = Coordinator::new(CoordinatorConfig::default())?;
    println!("coordinator up; XLA backend available: {}", coord.has_xla());

    let mut rng = Rng::new(3);
    // A mixed workload: artifact-shaped requests (route to XLA) and odd
    // shapes (served natively). Native dispatch is **adaptive**
    // (`DispatchConfig`, backed by exec::ExecPlanner): every shape is
    // recorded into an observed shape-mix histogram, and shapes with
    // batch peers in recent traffic are microbatched — a flushed batch
    // runs as ONE lane-fused sweep (ta::batch, vectorised across the
    // batch) instead of N independent signatures, the CPU serving hot
    // path for many short streams at small d. Shapes too rare to find
    // peers skip the linger and serve directly, so a long tail of odd
    // shapes costs no latency. `.with_native_batch(0)` is the documented
    // escape hatch disabling all native batching.
    let mut reqs = vec![];
    for i in 0..96 {
        let (stream, d, depth) = if i % 3 == 0 { (100, 3, 4) } else { (128, 4, 4) };
        reqs.push(Request::Signature {
            path: signax::data::random_path(&mut rng, stream, d, 0.2).into(),
            stream,
            d,
            depth,
        });
    }
    let t0 = Instant::now();
    let resps = coord.call_many(reqs);
    let dt = t0.elapsed();

    let mut by_backend = [0usize; 2];
    for r in &resps {
        match r.as_ref().expect("response").backend {
            Backend::Native => by_backend[0] += 1,
            Backend::Xla => by_backend[1] += 1,
        }
    }
    println!(
        "{} requests in {:.2}s ({:.0} req/s): {} native, {} xla",
        resps.len(),
        dt.as_secs_f64(),
        resps.len() as f64 / dt.as_secs_f64(),
        by_backend[0],
        by_backend[1]
    );
    let snap = coord.metrics().snapshot();
    println!("metrics: {}", snap.render());
    println!("dispatch: {}", snap.render_dispatch());
    println!(
        "dynamic batching: {} batches for {} rows ({:.1}% padding) — native \
         microbatches execute lane-fused",
        snap.batches,
        snap.real_rows,
        coord.metrics().padding_ratio() * 100.0
    );

    // Gradient serving (the backward operation as a service).
    let spec = signax::ta::SigSpec::new(4, 4)?;
    let path = signax::data::random_path(&mut rng, 128, 4, 0.2);
    let cot = rng.normal_vec(spec.sig_len(), 1.0);
    let resp = coord.call(Request::SignatureGrad {
        path: path.into(),
        stream: 128,
        d: 4,
        depth: 4,
        cotangent: cot.into(),
    })?;
    println!("gradient request served by {:?}: {} values", resp.backend, resp.values.len());

    // Stateful streaming through the same front door: open a session,
    // feed it incrementally ("keeping the signature up-to-date", §5.5),
    // query arbitrary intervals in O(1), and close it. The session table
    // is memory-bounded in production via `CoordinatorConfig::session`
    // (budget_bytes / ttl) — unbounded here for the demo. Feeds are
    // adaptive too: once two or more distinct sessions stream the same
    // spec, the planner opens a *feed lane* and concurrent feeds coalesce
    // into one lane-fused Path::update_batch sweep, bitwise identical per
    // session to scalar feeding; a lone feeder (like this demo) always
    // stays on the direct scalar path with no added latency.
    let open = coord.call(Request::OpenStream {
        points: signax::data::random_path(&mut rng, 8, 2, 0.2).into(),
        stream: 8,
        d: 2,
        depth: 3,
    })?;
    let sid = open.session.expect("open returns a session id");
    for _ in 0..4 {
        coord.call(Request::Feed {
            session: sid,
            points: rng.normal_vec(16 * 2, 0.2).into(),
            count: 16,
        })?;
    }
    let q = coord.call(Request::QueryInterval { session: sid, i: 10, j: 40 })?;
    let lq = coord.call(Request::LogSigQueryInterval { session: sid, i: 10, j: 40 })?;
    println!(
        "streaming session {sid:?}: 72 points fed, interval sig {} values, logsig {} values",
        q.values.len(),
        lq.values.len()
    );
    let snap = coord.metrics().snapshot();
    println!(
        "sessions: opened={} updates={} open={} resident={} bytes",
        snap.sessions_opened, snap.session_updates, snap.open_sessions, snap.session_bytes
    );
    coord.call(Request::CloseStream { session: sid })?;

    // Windowed feature extraction, server-maintained. Without windows, a
    // client wanting sliding-window signatures re-queries overlapping
    // intervals after every feed:
    //
    //     for k in delivered.. {            // the loop OpenWindow replaces
    //         let i = k * stride;
    //         coord.call(Request::QueryInterval { session, i, j: i + len - 1 })?;
    //     }
    //
    // — re-sending O(window) worth of interval bookkeeping per slide and
    // forcing the session to keep its whole history resident. With
    // `OpenWindow`, the server advances the window family inside each
    // feed (one O(1) stored-inverse Chen combination per slide — §5.5's
    // trick), buffers the emitted rows, and `PollWindow` drains them in
    // order; the rows are bitwise identical to the per-query loop above.
    // When a feed-lane flush holds two or more windowed sessions of one
    // spec, their slides advance in ONE lane-fused sweep (ta::batch
    // kernels, `RollingWindow::advance_batch`) instead of N scalar
    // loops — the `window_slide_batches` / `window_slides_batched`
    // counters below count those sweeps. Retention is O(window): the
    // session truncates dead history behind the oldest live window, so
    // a stream can run forever on a fixed byte budget.
    let wspec = WindowSpec { len: 16, stride: 4, logsig: None };
    let open = coord.call(Request::OpenWindow {
        points: signax::data::random_path(&mut rng, 8, 2, 0.2).into(),
        stream: 8,
        d: 2,
        depth: 3,
        window: wspec,
    })?;
    let wid = open.session.expect("open returns a session id");
    let mut slides = 0usize;
    for _ in 0..4 {
        coord.call(Request::Feed {
            session: wid,
            points: rng.normal_vec(16 * 2, 0.2).into(),
            count: 16,
        })?;
        // Poll at any cadence — undelivered slides buffer server-side
        // (and survive spill/restart; they are session state). Bounded
        // responses: `max_slides` pages the drain, and the response's
        // `window_remaining` says how many slides are still buffered —
        // loop until it reads 0.
        let dim = signax::ta::SigSpec::new(2, 3)?.sig_len();
        loop {
            let page =
                coord.call(Request::PollWindow { session: wid, max_slides: Some(2) })?;
            slides += page.values.len() / dim;
            if page.window_remaining == Some(0) {
                break;
            }
        }
    }
    let snap = coord.metrics().snapshot();
    println!(
        "windowed session {wid:?}: {slides} slides of len={} stride={} delivered \
         (window_slides={} window_polls={} slide_batches={} slides_batched={})",
        wspec.len,
        wspec.stride,
        snap.window_slides,
        snap.window_polls,
        snap.window_slide_batches,
        snap.window_slides_batched
    );
    if !snap.render_latency().is_empty() {
        println!("{}", snap.render_latency());
    }
    coord.call(Request::CloseStream { session: wid })?;
    Ok(())
}
