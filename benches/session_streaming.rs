//! Session-streaming benchmark: feed throughput vs client threads, one
//! streaming session per thread, everything served through
//! `Coordinator::call`. The sharded `Arc<Mutex<Path>>` session table must
//! scale this curve — a table-wide lock would flatline it. Writes the
//! machine-readable record the perf trajectory tracks:
//!
//!     cargo bench --bench session_streaming       # -> BENCH_sessions.json
//!
//! Acceptance target: distinct-session feed throughput grows with client
//! threads (>= 1.5x at 4 threads on a >= 4-way machine).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use signax::bench::{sessions_json, ChunkSizes};
use signax::coordinator::{Coordinator, CoordinatorConfig, Request, SessionId};
use signax::substrate::benchlib::fmt_secs;
use signax::substrate::pool::default_threads;
use signax::substrate::rng::Rng;

const D: usize = 3;
const DEPTH: usize = 4;
/// Mean-ish feed size; actual sizes are ragged (heavy-tailed in
/// `[FEED_POINTS/2, 2*FEED_POINTS]` via the shared seeded workload
/// generator), like real streaming traffic. Deterministic per thread,
/// so BENCH trajectories stay comparable across runs.
const FEED_POINTS: usize = 64;
const FEEDS_PER_THREAD: usize = 200;

fn main() -> anyhow::Result<()> {
    let hw = default_threads();
    let mut axis: Vec<usize> = [1usize, 2, 4, 8].into_iter().filter(|&t| t <= hw).collect();
    if axis.is_empty() {
        axis.push(1);
    }
    // No silent caps: say so when the acceptance point is not measurable.
    for &t in &[2usize, 4, 8] {
        if !axis.contains(&t) {
            eprintln!(
                "note: skipping {t}-thread series (machine has {hw} hardware threads)"
            );
        }
    }
    println!("{:<8} {:>8} {:>12} {:>12}", "threads", "feeds", "wall", "feeds/s");

    let mut records: Vec<(usize, f64, f64)> = vec![];
    for &threads in &axis {
        let coord = Coordinator::new(CoordinatorConfig::native_only())?;
        // One session per client thread, opened up-front through `call`.
        let ids: Vec<SessionId> = (0..threads)
            .map(|k| {
                let mut rng = Rng::new(0x5E55 ^ k as u64);
                let resp = coord.call(Request::OpenStream {
                    points: signax::data::random_path(&mut rng, 4, D, 0.1).into(),
                    stream: 4,
                    d: D,
                    depth: DEPTH,
                })?;
                resp.session.ok_or_else(|| anyhow::anyhow!("open returned no session id"))
            })
            .collect::<anyhow::Result<Vec<SessionId>>>()?;
        let errors = AtomicU64::new(0);
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for (k, &id) in ids.iter().enumerate() {
                let coord = &coord;
                let errors = &errors;
                scope.spawn(move || {
                    let mut rng = Rng::new(0xFEED ^ k as u64);
                    let sizes = ChunkSizes::new(FEED_POINTS / 2, FEED_POINTS * 2, 1.2);
                    for _ in 0..FEEDS_PER_THREAD {
                        let count = sizes.sample(&mut rng);
                        let points = rng.normal_vec(count * D, 0.1).into();
                        let req = Request::Feed { session: id, points, count };
                        if coord.call(req).is_err() {
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        anyhow::ensure!(errors.load(Ordering::Relaxed) == 0, "feed errors during bench");
        let feeds = threads * FEEDS_PER_THREAD;
        let rate = feeds as f64 / wall;
        println!("{:<8} {:>8} {:>12} {:>12.0}", threads, feeds, fmt_secs(wall), rate);
        records.push((threads, wall, rate));
    }

    if let (Some(&(t1, _, r1)), Some(&(tn, _, rn))) = (records.first(), records.last()) {
        if t1 == 1 && tn > 1 {
            println!(
                "\nscaling: {:.2}x feed throughput at {tn} threads (ideal {tn}x)",
                rn / r1
            );
        }
    }
    std::fs::write("BENCH_sessions.json", sessions_json(hw, &records))?;
    println!("wrote BENCH_sessions.json");
    Ok(())
}
