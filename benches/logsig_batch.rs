//! Batched-logsignature benchmark: lane-fused throughput vs per-path
//! scalar dispatch, swept over lane counts L ∈ {1, 4, 8, 16} and all
//! three bases (Expanded / Lyndon / Words) at d ∈ {2, 3}, depth 4, short
//! streams — logsignature parity for the serving regime `batch_lanes.rs`
//! measures on the signature side. Both sides run single-threaded so the
//! speedup isolates lane utilisation (the log + projection epilogue is
//! identical per-lane work on both sides, so it dilutes — never inflates —
//! the reported speedup). Writes the machine-readable record the perf
//! trajectory tracks:
//!
//!     cargo bench --bench logsig_batch             # -> BENCH_logsig.json
//!     cargo bench --bench logsig_batch -- --check  # CI structural smoke:
//!         reduced iterations; the bitwise gates (forward AND backward,
//!         every basis x lane point) plus JSON well-formedness are the
//!         assertions — timing-free, so CI noise cannot flake the job.
//!
//! Every timed point is first gated on bitwise equality between the
//! lane-fused rows and per-path scalar dispatch, so a lane-kernel or
//! epilogue regression fails the bench before any number is recorded.

use signax::bench::logsig_json;
use signax::logsignature::{
    logsignature_batch, logsignature_batch_vjp, logsignature_vjp_with, logsignature_with,
    LogSigBasis, LogSigPlan,
};
use signax::signature::SigConfig;
use signax::substrate::benchlib::{bench, black_box, fmt_secs, BenchConfig};
use signax::substrate::pool::default_threads;
use signax::substrate::rng::Rng;
use signax::ta::SigSpec;

const DEPTH: usize = 4;
const STREAM: usize = 32;

fn basis_name(b: LogSigBasis) -> &'static str {
    match b {
        LogSigBasis::Expanded => "expanded",
        LogSigBasis::Lyndon => "lyndon",
        LogSigBasis::Words => "words",
    }
}

fn main() -> anyhow::Result<()> {
    let check = std::env::args().any(|a| a == "--check");
    let cfg = if check {
        BenchConfig {
            warmup: 1,
            repeats: 5,
            budget: std::time::Duration::from_secs(2),
            min_repeats: 2,
        }
    } else {
        BenchConfig {
            warmup: 1,
            repeats: 30,
            budget: std::time::Duration::from_secs(6),
            min_repeats: 3,
        }
    };
    println!(
        "{:<9} {:<9} {:>3} {:>4} {:>12} {:>12} {:>8}",
        "op", "basis", "d", "L", "per-path", "lane-fused", "speedup"
    );
    let serial = SigConfig::serial();
    let mut records: Vec<(&str, &str, usize, usize, usize, f64, f64)> = vec![];
    for &d in &[2usize, 3] {
        let spec = SigSpec::new(d, DEPTH)?;
        for basis in [LogSigBasis::Expanded, LogSigBasis::Lyndon, LogSigBasis::Words] {
            let plan = LogSigPlan::new(&spec, basis)?;
            let dim = plan.dim();
            let name = basis_name(basis);
            for &lanes in &[1usize, 4, 8, 16] {
                let mut rng = Rng::new(0x106 ^ ((d as u64) << 8) ^ lanes as u64);
                let paths = signax::data::random_batch(&mut rng, lanes, STREAM, d, 0.2);
                let plen = STREAM * d;
                // Correctness gate before timing: lane-fused == per-path
                // scalar, bitwise, forward and backward.
                let batched = logsignature_batch(&paths, lanes, STREAM, &spec, &plan, 1)?;
                let cots = rng.normal_vec(lanes * dim, 1.0);
                let batched_grad =
                    logsignature_batch_vjp(&paths, lanes, STREAM, &spec, &plan, &cots, 1)?;
                for l in 0..lanes {
                    let single = logsignature_with(
                        &paths[l * plen..(l + 1) * plen],
                        STREAM,
                        &spec,
                        &plan,
                        &serial,
                    )?;
                    anyhow::ensure!(
                        batched[l * dim..(l + 1) * dim] == single[..],
                        "forward lane {l} of {name} d={d} L={lanes} diverged from scalar"
                    );
                    let single_grad = logsignature_vjp_with(
                        &paths[l * plen..(l + 1) * plen],
                        STREAM,
                        &spec,
                        &plan,
                        &serial,
                        &cots[l * dim..(l + 1) * dim],
                    )?;
                    anyhow::ensure!(
                        batched_grad[l * plen..(l + 1) * plen] == single_grad[..],
                        "backward lane {l} of {name} d={d} L={lanes} diverged from scalar"
                    );
                }
                let fwd_per_path = bench(&cfg, || {
                    for b in 0..lanes {
                        black_box(
                            logsignature_with(
                                &paths[b * plen..(b + 1) * plen],
                                STREAM,
                                &spec,
                                &plan,
                                &serial,
                            )
                            .unwrap(),
                        );
                    }
                })
                .best_secs();
                let fwd_lane = bench(&cfg, || {
                    black_box(logsignature_batch(&paths, lanes, STREAM, &spec, &plan, 1).unwrap());
                })
                .best_secs();
                println!(
                    "{:<9} {:<9} {:>3} {:>4} {:>12} {:>12} {:>7.2}x",
                    "forward",
                    name,
                    d,
                    lanes,
                    fmt_secs(fwd_per_path),
                    fmt_secs(fwd_lane),
                    fwd_per_path / fwd_lane
                );
                records.push(("forward", name, d, lanes, STREAM, fwd_per_path, fwd_lane));
                let bwd_per_path = bench(&cfg, || {
                    for b in 0..lanes {
                        black_box(
                            logsignature_vjp_with(
                                &paths[b * plen..(b + 1) * plen],
                                STREAM,
                                &spec,
                                &plan,
                                &serial,
                                &cots[b * dim..(b + 1) * dim],
                            )
                            .unwrap(),
                        );
                    }
                })
                .best_secs();
                let bwd_lane = bench(&cfg, || {
                    black_box(
                        logsignature_batch_vjp(&paths, lanes, STREAM, &spec, &plan, &cots, 1)
                            .unwrap(),
                    );
                })
                .best_secs();
                println!(
                    "{:<9} {:<9} {:>3} {:>4} {:>12} {:>12} {:>7.2}x",
                    "backward",
                    name,
                    d,
                    lanes,
                    fmt_secs(bwd_per_path),
                    fmt_secs(bwd_lane),
                    bwd_per_path / bwd_lane
                );
                records.push(("backward", name, d, lanes, STREAM, bwd_per_path, bwd_lane));
            }
        }
    }
    let json = logsig_json(default_threads(), DEPTH, &records);
    std::fs::write("BENCH_logsig.json", &json)?;
    println!("\nwrote BENCH_logsig.json");
    if check {
        // Structural smoke (timing-free, like adaptive_dispatch --check):
        // every basis x lane point passed its bitwise gate above; assert
        // the artifact parses and covers the full sweep.
        let parsed = signax::substrate::json::Json::parse(&json)?;
        let pts = parsed
            .get("points")
            .and_then(|p| p.as_arr())
            .ok_or_else(|| anyhow::anyhow!("points missing"))?;
        // 2 ops x 3 bases x 4 lane counts x 2 channel counts.
        anyhow::ensure!(pts.len() == 48, "expected 48 points, got {}", pts.len());
        println!("smoke ok: 48 points bitwise-gated and recorded");
    }
    Ok(())
}
