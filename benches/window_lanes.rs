//! Window-lane benchmark: lane-fused window-slide advancement
//! (`RollingWindow::advance_batch`, the ta::batch Chen kernels) against
//! the per-session scalar `advance` loop over the same feeds — the
//! serving regime where a feed-lane flush leaves N same-spec windowed
//! sessions each owing a run of slides, and advancing them one session
//! at a time leaves the SIMD lanes idle. Swept over lane counts
//! L ∈ {1, 4, 8, 16} x window length ∈ {16, 64} in **both precisions**
//! (f32 and f64) at d = 2, depth 4, stride 1. Both sides run
//! single-threaded so the speedup isolates lane utilisation.
//!
//! Each timed iteration rebuilds fresh window cursors over fixed,
//! pre-grown paths and re-advances the same slide run, so the measured
//! work is exactly the slide advancement (one stored-inverse ⊠ per
//! slide) plus identical per-side bookkeeping. The slide count per
//! window is chosen below the retention threshold, so the backing paths
//! are never truncated and every iteration replays identical work.
//! Every timed point is first gated on bitwise equality between the
//! batched lanes' emitted rows and the scalar per-session loop — in the
//! point's own precision — and a logsignature-window point is gated the
//! same way (shared projection epilogue), untimed. Writes the
//! machine-readable record the perf trajectory tracks:
//!
//!     cargo bench --bench window_lanes             # -> BENCH_window.json
//!     cargo bench --bench window_lanes -- --check  # CI smoke: reduced
//!         iteration count, structural + bitwise gates, relaxed floor
//!
//! Acceptance target: >= 1.5x batched-vs-scalar at L = 16, d = 2
//! (window 64, f32) in the full run, recorded in BENCH_window.json.

use signax::bench::window_json;
use signax::logsignature::LogSigBasis;
use signax::path::{Path, RollingWindow, WindowSpec};
use signax::substrate::benchlib::{bench, black_box, fmt_secs, BenchConfig};
use signax::substrate::pool::default_threads;
use signax::substrate::rng::Rng;
use signax::ta::{Elem, SigSpec};

const D: usize = 2;
const DEPTH: usize = 4;
const STRIDE: usize = 1;

/// `(prec, basis, d, depth, window_len, stride, lanes, scalar_s,
/// batched_s)` — the [`window_json`] point format.
type Record = (&'static str, &'static str, usize, usize, usize, usize, usize, f64, f64);

/// Paths for one lane group: `lanes` independent streams of `points`
/// steps each, fully grown up front (windows attach per iteration).
fn grow_paths<E: Elem>(spec: &SigSpec, lanes: usize, points: usize, seed: u64) -> Vec<Path<E>> {
    let mut rng = Rng::new(seed);
    (0..lanes)
        .map(|_| {
            let pts: Vec<E> = signax::data::random_path(&mut rng, points, spec.d(), 0.2)
                .into_iter()
                .map(E::from_f32)
                .collect();
            Path::new(spec, &pts, points).expect("valid bench path")
        })
        .collect()
}

/// One (prec, window_len, lanes) cell: bitwise-gate `advance_batch`
/// against the per-session scalar loop, then time both sides over fresh
/// window cursors on the same paths.
fn sweep_point<E: Elem>(
    cfg: &BenchConfig,
    prec: &'static str,
    wlen: usize,
    lanes: usize,
    records: &mut Vec<Record>,
) -> anyhow::Result<()> {
    let spec = SigSpec::new(D, DEPTH)?;
    let wspec = WindowSpec { len: wlen, stride: STRIDE, logsig: None };
    // Slides per iteration, held under the retention threshold
    // ((slides + 1) * stride < len) so `advance` never truncates the
    // paths and every iteration replays the identical slide run.
    let slides = wlen - 2;
    let points = wlen + (slides - 1) * STRIDE;
    let mut paths: Vec<Path<E>> =
        grow_paths(&spec, lanes, points, 0x51DE ^ ((wlen as u64) << 8) ^ lanes as u64);

    // Correctness gate before timing: batched == scalar, bitwise, lane
    // by lane, over the exact slide run the timed loop replays.
    let mut scalar_rows: Vec<Vec<E>> = Vec::with_capacity(lanes);
    for p in paths.iter_mut() {
        let mut w = RollingWindow::new(&spec, wspec)?;
        anyhow::ensure!(w.advance(p)? == slides, "scalar slide count drifted");
        scalar_rows.push(w.poll().1);
    }
    let mut wins: Vec<RollingWindow<E>> =
        (0..lanes).map(|_| RollingWindow::new(&spec, wspec).unwrap()).collect();
    {
        let mut prefs: Vec<&mut Path<E>> = paths.iter_mut().collect();
        let mut wrefs: Vec<&mut RollingWindow<E>> = wins.iter_mut().collect();
        anyhow::ensure!(
            RollingWindow::advance_batch(&mut prefs, &mut wrefs)? == slides * lanes,
            "batched slide count drifted"
        );
    }
    for (l, w) in wins.iter_mut().enumerate() {
        anyhow::ensure!(
            w.poll().1 == scalar_rows[l],
            "lane {l} of {prec} len={wlen} L={lanes} diverged from scalar advance"
        );
    }
    for (l, p) in paths.iter().enumerate() {
        anyhow::ensure!(p.base() == 0, "lane {l} was truncated: iterations would not replay");
    }

    let scalar_s = bench(cfg, || {
        for p in paths.iter_mut() {
            let mut w = RollingWindow::new(&spec, wspec).unwrap();
            black_box(w.advance(p).unwrap());
        }
    })
    .best_secs();
    let batched_s = bench(cfg, || {
        let mut wins: Vec<RollingWindow<E>> =
            (0..lanes).map(|_| RollingWindow::new(&spec, wspec).unwrap()).collect();
        let mut prefs: Vec<&mut Path<E>> = paths.iter_mut().collect();
        let mut wrefs: Vec<&mut RollingWindow<E>> = wins.iter_mut().collect();
        black_box(RollingWindow::advance_batch(&mut prefs, &mut wrefs).unwrap());
    })
    .best_secs();
    println!(
        "{:>4} {:>4} {:>4} {:>7} {:>12} {:>12} {:>7.2}x",
        prec,
        wlen,
        lanes,
        slides * lanes,
        fmt_secs(scalar_s),
        fmt_secs(batched_s),
        scalar_s / batched_s
    );
    records.push((prec, "sig", D, DEPTH, wlen, STRIDE, lanes, scalar_s, batched_s));
    Ok(())
}

/// Logsignature windows share the batched sweep's projection epilogue
/// (`project_sigs_into`): gate one mixed-geometry group bitwise against
/// the scalar loop, untimed (plan construction would dominate a timing).
fn logsig_gate() -> anyhow::Result<()> {
    let spec = SigSpec::new(D, 3)?;
    let wspec = WindowSpec { len: 16, stride: 2, logsig: Some(LogSigBasis::Words) };
    let lanes = 8;
    let mut paths: Vec<Path<f32>> = grow_paths(&spec, lanes, 40, 0x10651);
    let mut twins: Vec<Path<f32>> = grow_paths(&spec, lanes, 40, 0x10651);
    let mut scalar_rows: Vec<Vec<f32>> = Vec::with_capacity(lanes);
    for p in twins.iter_mut() {
        let mut w = RollingWindow::new(&spec, wspec)?;
        w.advance(p)?;
        scalar_rows.push(w.poll().1);
    }
    let mut wins: Vec<RollingWindow<f32>> =
        (0..lanes).map(|_| RollingWindow::new(&spec, wspec).unwrap()).collect();
    let mut prefs: Vec<&mut Path<f32>> = paths.iter_mut().collect();
    let mut wrefs: Vec<&mut RollingWindow<f32>> = wins.iter_mut().collect();
    RollingWindow::advance_batch(&mut prefs, &mut wrefs)?;
    for (l, w) in wins.iter_mut().enumerate() {
        anyhow::ensure!(
            w.poll().1 == scalar_rows[l],
            "logsig lane {l} diverged from scalar advance"
        );
    }
    println!("logsig gate ok: {lanes} Words-basis lanes bitwise equal to scalar");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let check = std::env::args().any(|a| a == "--check");
    let cfg = if check {
        BenchConfig {
            warmup: 2,
            repeats: 20,
            budget: std::time::Duration::from_secs(4),
            min_repeats: 5,
        }
    } else {
        BenchConfig {
            warmup: 1,
            repeats: 30,
            budget: std::time::Duration::from_secs(6),
            min_repeats: 3,
        }
    };
    println!(
        "{:>4} {:>4} {:>4} {:>7} {:>12} {:>12} {:>8}",
        "prec", "len", "L", "slides", "scalar", "batched", "speedup"
    );
    let mut records: Vec<Record> = vec![];
    for &wlen in &[16usize, 64] {
        for &lanes in &[1usize, 4, 8, 16] {
            sweep_point::<f32>(&cfg, "f32", wlen, lanes, &mut records)?;
            sweep_point::<f64>(&cfg, "f64", wlen, lanes, &mut records)?;
        }
    }
    logsig_gate()?;
    let json = window_json(default_threads(), &records);
    std::fs::write("BENCH_window.json", &json)?;
    println!("\nwrote BENCH_window.json");

    let speedup_at = |prec: &str, wlen: usize, lanes: usize| {
        records
            .iter()
            .find(|r| r.0 == prec && r.4 == wlen && r.6 == lanes)
            .map(|r| r.7 / r.8)
            .expect("acceptance point measured")
    };
    if check {
        // Structural smoke: the full sweep grid was measured and the
        // written record reads back through the in-tree parser.
        for &prec in &["f32", "f64"] {
            for &wlen in &[16usize, 64] {
                for &lanes in &[1usize, 4, 8, 16] {
                    anyhow::ensure!(
                        records.iter().any(|r| r.0 == prec && r.4 == wlen && r.6 == lanes),
                        "sweep missing point {prec} len={wlen} L={lanes}"
                    );
                }
            }
        }
        let doc = signax::substrate::json::Json::parse(&json)?;
        let pts = doc
            .get("points")
            .and_then(|p| p.as_arr())
            .ok_or_else(|| anyhow::anyhow!("BENCH_window.json has no points[]"))?;
        anyhow::ensure!(pts.len() == records.len(), "BENCH_window.json dropped points");
        // Relaxed floor (full-run acceptance is >= 1.5x): only a genuine
        // kernel regression should trip this on a noisy CI runner.
        let s = speedup_at("f32", 64, 16);
        anyhow::ensure!(
            s >= 1.1,
            "window-lane smoke FAILED: speedup at d=2, len=64, L=16 is {s:.2}x \
             (smoke floor 1.1x; full-run acceptance >= 1.5x)"
        );
        println!("smoke ok: {} points, speedup at len=64 L=16 = {s:.2}x", pts.len());
    } else {
        let s = speedup_at("f32", 64, 16);
        anyhow::ensure!(
            s >= 1.5,
            "window-lane acceptance FAILED: batched-vs-scalar at d=2, len=64, L=16 \
             is {s:.2}x (target >= 1.5x)"
        );
        println!("acceptance ok: batched-vs-scalar at d=2, len=64, L=16 = {s:.2}x");
    }
    Ok(())
}
