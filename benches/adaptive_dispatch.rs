//! Adaptive-dispatch benchmark: the same mixed-shape serving workload run
//! under **static** dispatch (every native shape lingers up to the full
//! microbatch capacity — the pre-planner behaviour) and **adaptive**
//! dispatch (the `exec::ExecPlanner` quotes per-shape capacity from the
//! observed shape mix: hot shapes lane-fuse, rare shapes skip the linger;
//! cross-session feeds coalesce through the feed lane). Writes the
//! machine-readable record the perf trajectory tracks:
//!
//!     cargo bench --bench adaptive_dispatch             # -> BENCH_dispatch.json
//!     cargo bench --bench adaptive_dispatch -- --check  # CI smoke: reduced
//!         workload plus hard structural gates (rare shapes must bypass
//!         the batcher under adaptive dispatch; cross-session feeds must
//!         coalesce into fused sweeps), so planner regressions fail CI
//!         instead of only skewing uploaded artifacts
//!
//! The workload: one dominant shape (most of the traffic, issued
//! concurrently so it coalesces) plus a long tail of rare unique shapes
//! (issued alone — under static dispatch each idles out the linger in its
//! own one-row batch; under adaptive dispatch each serves directly), then
//! a cross-session streaming phase feeding one spec from several sessions.

use std::time::{Duration, Instant};

use signax::bench::dispatch_json;
use signax::coordinator::{
    Coordinator, CoordinatorConfig, DispatchConfig, MetricsSnapshot, Request,
};
use signax::substrate::benchlib::fmt_secs;
use signax::substrate::pool::default_threads;
use signax::substrate::rng::Rng;

const HOT: (usize, usize, usize) = (32, 3, 4); // (stream, d, depth)
const DEPTH_TAIL: usize = 3;
const LINGER: Duration = Duration::from_millis(2);

fn coordinator(adaptive: bool) -> anyhow::Result<Coordinator> {
    // "static" reproduces the pre-planner behaviour faithfully: every
    // native shape always lingers up to the full capacity and feeds are
    // never lane-fused (the feed lane did not exist).
    Coordinator::new(CoordinatorConfig {
        linger: LINGER,
        dispatch: DispatchConfig { adaptive, feed_lanes: adaptive, ..DispatchConfig::default() },
        ..CoordinatorConfig::native_only()
    })
}

fn hot_request(rng: &mut Rng) -> Request {
    let (stream, d, depth) = HOT;
    Request::Signature {
        path: signax::data::random_path(rng, stream, d, 0.2).into(),
        stream,
        d,
        depth,
    }
}

/// A rare shape unique to `k`: stream lengths nothing else in the
/// workload uses, so no two rare requests can share a microbatch.
fn rare_request(rng: &mut Rng, k: usize) -> Request {
    let stream = 40 + 2 * k;
    Request::Signature {
        path: signax::data::random_path(rng, stream, 2, 0.2).into(),
        stream,
        d: 2,
        depth: DEPTH_TAIL,
    }
}

struct PhaseResult {
    requests: usize,
    wall: f64,
    snap: MetricsSnapshot,
}

/// Mixed stateless phase: waves of concurrent hot requests, each wave
/// followed by one lone rare-shape request (the latency-tail victim of
/// static dispatch).
fn run_mixed(coord: &Coordinator, waves: usize, hot_per_wave: usize) -> anyhow::Result<PhaseResult> {
    let mut rng = Rng::new(0xD15A);
    let mut requests = 0usize;
    let t0 = Instant::now();
    for wave in 0..waves {
        let batch: Vec<Request> = (0..hot_per_wave).map(|_| hot_request(&mut rng)).collect();
        requests += batch.len();
        for r in coord.call_many(batch) {
            r?;
        }
        coord.call(rare_request(&mut rng, wave))?;
        requests += 1;
    }
    Ok(PhaseResult { requests, wall: t0.elapsed().as_secs_f64(), snap: coord.metrics().snapshot() })
}

/// Streaming phase: `sessions` sessions on one spec, fed concurrently in
/// rounds — adaptive dispatch coalesces the rounds into fused feed-lane
/// sweeps once the planner has seen the distinct feeders.
fn run_feeds(coord: &Coordinator, sessions: usize, rounds: usize) -> anyhow::Result<PhaseResult> {
    let mut rng = Rng::new(0xFEED);
    let mut ids = vec![];
    for _ in 0..sessions {
        let resp = coord.call(Request::OpenStream {
            points: signax::data::random_path(&mut rng, 4, 3, 0.2).into(),
            stream: 4,
            d: 3,
            depth: 4,
        })?;
        ids.push(resp.session.ok_or_else(|| anyhow::anyhow!("open returned no session"))?);
    }
    let mut requests = sessions;
    let t0 = Instant::now();
    for _ in 0..rounds {
        let batch: Vec<Request> = ids
            .iter()
            .map(|&sid| Request::Feed {
                session: sid,
                points: rng.normal_vec(8 * 3, 0.2).into(),
                count: 8,
            })
            .collect();
        requests += batch.len();
        for r in coord.call_many(batch) {
            r?;
        }
    }
    Ok(PhaseResult { requests, wall: t0.elapsed().as_secs_f64(), snap: coord.metrics().snapshot() })
}

fn main() -> anyhow::Result<()> {
    let check = std::env::args().any(|a| a == "--check");
    let (waves, hot_per_wave, sessions, rounds) =
        if check { (12, 6, 4, 8) } else { (32, 8, 6, 24) };

    println!(
        "{:<9} {:<7} {:>5} {:>10} {:>12} {:>8} {:>8} {:>8} {:>6}",
        "mode", "phase", "reqs", "wall", "mean_lat", "batches", "scalar", "lane", "feed"
    );
    let mut records: Vec<(&str, &str, usize, f64, f64, u64, u64, u64, u64)> = vec![];
    let mut report = |mode: &'static str,
                      phase: &'static str,
                      res: &PhaseResult,
                      prev: Option<&MetricsSnapshot>| {
        // Per-phase deltas against the previous snapshot of the same
        // coordinator (phases share one metrics struct) — including the
        // latency, which the snapshot only exposes as a running mean:
        // reconstruct each phase's own mean from the totals so the feeds
        // row is not skewed by the mixed phase's deliberate lingers.
        let d = |f: fn(&MetricsSnapshot) -> u64| {
            f(&res.snap) - prev.map_or(0, f)
        };
        let total_s =
            |s: &MetricsSnapshot| s.mean_latency.as_secs_f64() * s.requests as f64;
        let phase_reqs = d(|s| s.requests).max(1);
        let lat_us =
            (total_s(&res.snap) - prev.map_or(0.0, total_s)) / phase_reqs as f64 * 1e6;
        println!(
            "{:<9} {:<7} {:>5} {:>10} {:>10}us {:>8} {:>8} {:>8} {:>6}",
            mode,
            phase,
            res.requests,
            fmt_secs(res.wall),
            format!("{lat_us:.0}"),
            d(|s| s.batches),
            d(|s| s.dispatch_scalar),
            d(|s| s.dispatch_lane_fused),
            d(|s| s.feed_lane_batches),
        );
        records.push((
            mode,
            phase,
            res.requests,
            res.wall,
            lat_us,
            d(|s| s.batches),
            d(|s| s.dispatch_scalar),
            d(|s| s.dispatch_lane_fused),
            d(|s| s.feed_lane_batches),
        ));
    };

    let mut gate: Vec<(String, bool)> = vec![];
    for (mode, adaptive) in [("static", false), ("adaptive", true)] {
        let coord = coordinator(adaptive)?;
        let mixed = run_mixed(&coord, waves, hot_per_wave)?;
        report(mode, "mixed", &mixed, None);
        let feeds = run_feeds(&coord, sessions, rounds)?;
        report(mode, "feeds", &feeds, Some(&mixed.snap));
        if adaptive {
            // Structural gates (timing-free, so CI noise cannot flake
            // them). A request served through the batcher contributes
            // exactly one `real_rows`; a direct (planner-bypassed) serve
            // contributes none — so `requests - real_rows` counts the
            // bypasses exactly, and a planner regression that routes
            // everything through the batcher (real_rows == requests,
            // like the static run) fails this gate.
            let bypassed = mixed.requests as u64 - mixed.snap.real_rows;
            gate.push((
                format!(
                    "adaptive run must serve rare shapes directly \
                     ({bypassed} of {waves} rare requests bypassed the batcher)"
                ),
                bypassed >= waves as u64 - 4, // first few land pre-warm-up
            ));
            gate.push((
                format!(
                    "cross-session feeds must coalesce into fused sweeps \
                     (feed_lane_batches = {})",
                    feeds.snap.feed_lane_batches
                ),
                feeds.snap.feed_lane_batches > 0,
            ));
        } else {
            gate.push((
                format!(
                    "static run must keep every stateless request on the batcher \
                     (real_rows {} == {} requests)",
                    mixed.snap.real_rows, mixed.requests
                ),
                mixed.snap.real_rows == mixed.requests as u64,
            ));
            gate.push((
                format!(
                    "static run must never lane-fuse feeds \
                     (feed_lane_batches = {})",
                    feeds.snap.feed_lane_batches
                ),
                feeds.snap.feed_lane_batches == 0,
            ));
        }
    }

    std::fs::write("BENCH_dispatch.json", dispatch_json(default_threads(), &records))?;
    println!("\nwrote BENCH_dispatch.json");

    if check {
        for (what, ok) in &gate {
            anyhow::ensure!(*ok, "adaptive-dispatch smoke FAILED: {what}");
            println!("smoke ok: {what}");
        }
    }
    Ok(())
}
