//! Microbenchmark: the fused multiply-exponentiate (§4.1) vs the
//! conventional exp-then-⊠, per (d, N) — the op-level ground truth behind
//! Tables 1–4, and the primary target of the §Perf optimization loop.

use signax::substrate::benchlib::{bench, black_box, fmt_secs, BenchConfig};
use signax::substrate::rng::Rng;
use signax::ta::fused::{fused_mexp, unfused_mexp_into};
use signax::ta::opcount;
use signax::ta::{SigSpec, Workspace};

fn main() {
    let cfg = BenchConfig {
        warmup: 3,
        repeats: 30,
        budget: std::time::Duration::from_secs(2),
        min_repeats: 5,
    };
    println!(
        "{:<10} {:>12} {:>12} {:>8} {:>10} {:>12}",
        "(d, N)", "fused", "unfused", "speedup", "C/F muls", "fused ns/mul"
    );
    for (d, n) in [(2usize, 5usize), (3, 5), (4, 4), (4, 7), (5, 5), (7, 7), (4, 9)] {
        let spec = SigSpec::new(d, n).unwrap();
        let mut ws = Workspace::new(&spec);
        let mut rng = Rng::new(1);
        let a = rng.normal_vec(spec.sig_len(), 0.5);
        let z = rng.normal_vec(d, 0.5);
        let mut buf = a.clone();
        let fused = bench(&cfg, || {
            buf.copy_from_slice(&a);
            fused_mexp(&spec, &mut buf, &z, &mut ws);
            black_box(buf[0]);
        })
        .best_secs();
        let mut out = spec.zeros();
        let unfused = bench(&cfg, || {
            unfused_mexp_into(&spec, &a, &z, &mut out, &mut ws);
            black_box(out[0]);
        })
        .best_secs();
        let muls = opcount::fused_muls(d as u64, n as u64) as f64;
        println!(
            "{:<10} {:>12} {:>12} {:>7.2}x {:>10.1} {:>12.3}",
            format!("({d}, {n})"),
            fmt_secs(fused),
            fmt_secs(unfused),
            unfused / fused,
            opcount::conventional_muls(d as u64, n as u64) as f64 / muls,
            fused * 1e9 / muls,
        );
    }
}
