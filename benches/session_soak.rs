//! Million-session rolling-window soak: the scale proof for the
//! server-maintained sliding-signature feature. Four stages:
//!
//! - **bitwise gate** (always): every slide a windowed session emits —
//!   across specs, strides, signature and logsignature outputs, both
//!   precisions, ragged feed sizes — is bitwise the per-query answer of
//!   an untruncated twin session over the same interval.
//! - **memory ceiling** (always): a window session's resident bytes stay
//!   O(window) while the points flowing through it grow O(history); an
//!   unbounded plain session holding the same history is the yardstick.
//! - **speedup**: server-maintained sliding windows (feed + poll, one
//!   O(1) stored-inverse combination per slide) vs the client-side
//!   recompute-per-slide loop they replace (a fresh `signature()` over
//!   each window's points). Acceptance: >= 5x at window >= 64 in the
//!   full run.
//! - **soak**: open a ~1M-session fleet of mixed specs through
//!   `Coordinator::call` under a resident-byte budget sized to a third
//!   of the fleet, then drive seeded Zipf feed/poll traffic through the
//!   eviction/reload churn that budget forces, then drain every window.
//!   The p99 feed/poll latencies (log2-bucket histograms, upper-edge
//!   quantiles) gate an SLO in the full run.
//!
//!     cargo bench --bench session_soak             # -> BENCH_soak.json
//!     cargo bench --bench session_soak -- --check  # CI smoke: ~3k
//!         sessions, timing-free (bitwise + memory + churn + structural
//!         gates only, so CI noise cannot flake it)

use std::sync::Arc;
use std::time::{Duration, Instant};

use signax::bench::{soak_json, ChunkSizes, Workload};
use signax::coordinator::{
    Coordinator, CoordinatorConfig, Metrics, Request, RequestKind, SessionConfig, SessionId,
    SessionManager,
};
use signax::logsignature::{LogSigBasis, LogSigPlan};
use signax::path::{Path, WindowSpec};
use signax::signature::signature;
use signax::state::SpillConfig;
use signax::substrate::benchlib::fmt_secs;
use signax::substrate::pool::default_threads;
use signax::substrate::rng::Rng;
use signax::ta::{Precision, Rows, SigSpec};

/// One session archetype in the mixed-spec fleet (rank r runs profile
/// `r % PROFILES`). All are lightweight: 2-point seeds, shallow specs.
struct Profile {
    d: usize,
    depth: usize,
    prec: Precision,
    window: Option<WindowSpec>,
}

fn profiles() -> Vec<Profile> {
    vec![
        Profile {
            d: 2,
            depth: 2,
            prec: Precision::F32,
            window: Some(WindowSpec { len: 8, stride: 4, logsig: None }),
        },
        Profile {
            d: 3,
            depth: 2,
            prec: Precision::F32,
            window: Some(WindowSpec { len: 6, stride: 3, logsig: Some(LogSigBasis::Words) }),
        },
        Profile {
            d: 2,
            depth: 3,
            prec: Precision::F64,
            window: Some(WindowSpec { len: 8, stride: 2, logsig: None }),
        },
        Profile { d: 2, depth: 2, prec: Precision::F32, window: None },
    ]
}

fn widen(v: &[f32]) -> Vec<f64> {
    v.iter().copied().map(f64::from).collect()
}

fn rows_for(prec: Precision, v: Vec<f32>) -> Rows {
    match prec {
        Precision::F32 => v.into(),
        Precision::F64 => widen(&v).into(),
    }
}

fn manager(budget: Option<usize>, spill: SpillConfig) -> SessionManager {
    SessionManager::with_config(
        Arc::new(Metrics::default()),
        SessionConfig { budget_bytes: budget, spill, ..SessionConfig::default() },
    )
    .unwrap()
}

/// Slide row `k` of a packed poll result == the expected Rows? (Both
/// sides are the session's native precision; a width mismatch is false.)
fn row_eq(rows: &Rows, k: usize, dim: usize, want: &Rows) -> bool {
    match (rows, want) {
        (Rows::F32(v), Rows::F32(w)) => v[k * dim..(k + 1) * dim] == w[..],
        (Rows::F64(v), Rows::F64(w)) => v[k * dim..(k + 1) * dim] == w[..],
        _ => false,
    }
}

/// The gate everything else rides on: windowed output == per-query
/// output, bitwise, across specs x strides x bases x precisions x
/// ragged feeds. The twin session never truncates (plain sessions keep
/// full history), so this also pins the retention watermark: truncation
/// must not change a single emitted bit.
fn bitwise_gate() -> anyhow::Result<()> {
    let m = manager(None, SpillConfig::None);
    let chunk_sizes = ChunkSizes::new(1, 7, 1.2);
    let mut rng = Rng::new(0x50AB17);
    let mut combos = 0usize;
    for (d, depth) in [(2usize, 3usize), (3, 2)] {
        for prec in [Precision::F32, Precision::F64] {
            let spec = SigSpec::with_dtype(d, depth, prec)?;
            for (len, stride) in [(4usize, 2usize), (6, 3), (5, 1)] {
                for basis in [None, Some(LogSigBasis::Words)] {
                    let wspec = WindowSpec { len, stride, logsig: basis };
                    let plan = match basis {
                        Some(b) => Some(LogSigPlan::new(&spec, b)?),
                        None => None,
                    };
                    let dim = match &plan {
                        Some(p) => p.dim(),
                        None => spec.sig_len(),
                    };
                    let seed = rng.normal_vec(3 * d, 0.3);
                    let (wid, _) = m.open_window(&spec, &rows_for(prec, seed.clone()), 3, wspec)?;
                    let twin = m.open(&spec, &rows_for(prec, seed), 3)?;
                    let mut slides_seen = 0u64;
                    for _ in 0..6 {
                        let n = chunk_sizes.sample(&mut rng);
                        let pts = rows_for(prec, rng.normal_vec(n * d, 0.3));
                        m.feed(wid, &pts, n)?;
                        m.feed(twin, &pts, n)?;
                        let (first, rows) = m.poll_window(wid)?;
                        anyhow::ensure!(first == slides_seen, "slide cursor skipped or replayed");
                        for k in 0..rows.len() / dim {
                            let i = (first as usize + k) * stride;
                            let j = i + len - 1;
                            let want = match &plan {
                                Some(p) => m.logsig_query(twin, i, j, p)?,
                                None => m.query(twin, i, j)?,
                            };
                            anyhow::ensure!(
                                row_eq(&rows, k, dim, &want),
                                "slide {} of d={d} depth={depth} {prec:?} len={len} \
                                 stride={stride} basis={basis:?} diverged from per-query twin",
                                first as usize + k
                            );
                            slides_seen += 1;
                        }
                    }
                    anyhow::ensure!(
                        slides_seen >= 2,
                        "combo len={len} stride={stride} emitted too few slides to gate"
                    );
                    m.close(wid)?;
                    m.close(twin)?;
                    combos += 1;
                }
            }
        }
    }
    println!("bitwise gate: {combos} spec/stride/basis/precision combos, all slides exact");
    Ok(())
}

/// O(window) retention vs O(history) growth, measured in accounted
/// resident bytes. Returns `(history_points, windowed_bytes,
/// unbounded_bytes)` rows for the JSON record.
fn memory_ceiling() -> anyhow::Result<Vec<(usize, usize, usize)>> {
    let spec = SigSpec::new(2, 2)?;
    let wspec = WindowSpec { len: 64, stride: 1, logsig: None };
    let windowed = manager(None, SpillConfig::None);
    let unbounded = manager(None, SpillConfig::None);
    let mut rng = Rng::new(0xCE11);
    let seed = rng.normal_vec(2 * 2, 0.3);
    let (wid, _) = windowed.open_window(&spec, &seed.clone().into(), 2, wspec)?;
    let pid = unbounded.open(&spec, &seed.into(), 2)?;
    let mut rows = vec![];
    let mut fed = 2usize;
    for target in [2048usize, 4096] {
        while fed < target {
            let n = 64.min(target - fed);
            let pts: Rows = rng.normal_vec(n * 2, 0.3).into();
            windowed.feed(wid, &pts, n)?;
            unbounded.feed(pid, &pts, n)?;
            // Drain as a client would; undelivered rows are state, so an
            // unpolled window would (correctly) grow without bound.
            windowed.poll_window(wid)?;
            fed += n;
        }
        rows.push((fed, windowed.resident_bytes(), unbounded.resident_bytes()));
    }
    let (h1, w1, u1) = rows[0];
    let (h2, w2, u2) = rows[1];
    anyhow::ensure!(
        u2 >= 8 * w2,
        "O(window)/O(history) separation missing at {h2} points: windowed {w2}B vs plain {u2}B"
    );
    anyhow::ensure!(
        w2 <= w1 + w1 / 4,
        "window session kept growing with history: {w1}B at {h1} -> {w2}B at {h2}"
    );
    anyhow::ensure!(u2 > u1, "plain control failed to grow (bad yardstick)");
    println!(
        "memory ceiling: windowed {w1}B @ {h1} pts -> {w2}B @ {h2} pts (plain: {u1}B -> {u2}B)"
    );
    Ok(rows)
}

/// Windowed serving vs the recompute-per-slide client loop it replaces.
/// Returns `(window_len, recompute_s, windowed_s)`.
fn speedup(window_lens: &[usize], slides: usize) -> anyhow::Result<Vec<(usize, f64, f64)>> {
    let spec = SigSpec::new(2, 3)?;
    let mut out = vec![];
    for &len in window_lens {
        let total = len + slides; // stride 1: one slide per extra point
        let mut rng = Rng::new(0x5BEE ^ len as u64);
        let all = rng.normal_vec(total * 2, 0.3);

        // Client-side recompute: one fresh signature per slide over the
        // window's raw points (what callers do without OpenWindow).
        let t0 = Instant::now();
        let mut sink = 0.0f32;
        for k in 0..=slides {
            let sig = signature(&all[k * 2..(k + len) * 2], len, &spec);
            sink += sig[0];
        }
        let recompute_s = t0.elapsed().as_secs_f64();
        anyhow::ensure!(sink.is_finite(), "recompute produced non-finite output");

        // Server-maintained: seed the window, then feed point-by-point
        // batches and poll — the timed region covers extend + slide +
        // drain, the whole serving cost.
        let m = manager(None, SpillConfig::None);
        let wspec = WindowSpec { len, stride: 1, logsig: None };
        let t0 = Instant::now();
        let (wid, _) = m.open_window(&spec, &all[..len * 2].to_vec().into(), len, wspec)?;
        let mut delivered = 0usize;
        for chunk in all[len * 2..].chunks(64 * 2) {
            let n = chunk.len() / 2;
            m.feed(wid, &chunk.to_vec().into(), n)?;
            let (_, rows) = m.poll_window(wid)?;
            delivered += rows.len() / spec.sig_len();
        }
        let windowed_s = t0.elapsed().as_secs_f64();
        anyhow::ensure!(
            delivered == slides + 1,
            "windowed arm delivered {delivered} slides, expected {}",
            slides + 1
        );
        println!(
            "speedup: window {len}: recompute {} vs windowed {} ({:.1}x)",
            fmt_secs(recompute_s),
            fmt_secs(windowed_s),
            recompute_s / windowed_s
        );
        out.push((len, recompute_s, windowed_s));
    }
    Ok(out)
}

fn p99_us(coord: &Coordinator, kind: RequestKind) -> f64 {
    coord.metrics().latency_of(kind).quantile(0.99).as_secs_f64() * 1e6
}

fn main() -> anyhow::Result<()> {
    let check = std::env::args().any(|a| a == "--check");
    let hw = default_threads();

    bitwise_gate()?;
    let memory = memory_ceiling()?;
    let speedups = if check {
        // Reduced, ungated: timing on a loaded CI box proves nothing.
        speedup(&[16], 200)?
    } else {
        speedup(&[64, 256], 20_000)?
    };
    if !check {
        for &(len, recompute, windowed) in &speedups {
            if len >= 64 {
                anyhow::ensure!(
                    recompute / windowed >= 5.0,
                    "windowed serving under 5x recompute at window {len}: {:.1}x",
                    recompute / windowed
                );
            }
        }
    }

    // ---- The soak: a mixed-spec fleet under Zipf traffic. ----
    let sessions: usize = if check { 3_000 } else { 1_000_000 };
    let events: usize = if check { 12_000 } else { 3_000_000 };
    let profs = profiles();

    // Budget a third of the fleet's measured resident footprint, so the
    // open flood spills cold sessions and Zipf traffic reloads them.
    let per_avg = {
        let mut total = 0usize;
        for p in &profs {
            let spec = SigSpec::with_dtype(p.d, p.depth, p.prec)?;
            total += match p.prec {
                Precision::F32 => {
                    Path::<f32>::new(&spec, &vec![0.0f32; 2 * p.d], 2)?.storage_bytes()
                }
                Precision::F64 => {
                    Path::<f64>::new(&spec, &vec![0.0f64; 2 * p.d], 2)?.storage_bytes()
                }
            };
        }
        total / profs.len()
    };
    let mut cfg = CoordinatorConfig::native_only().with_native_batch(0);
    cfg.session = SessionConfig {
        budget_bytes: Some((per_avg * sessions / 3).max(per_avg * 4)),
        spill: SpillConfig::Memory,
        ..SessionConfig::default()
    };
    let coord = Coordinator::new(cfg)?;

    println!("\n{:<8} {:>10} {:>12} {:>12} {:>12}", "phase", "events", "wall", "ops/s", "p99");
    let mut phases: Vec<(&str, usize, f64, f64, f64)> = vec![];

    // Phase 1: open the fleet.
    let mut ids: Vec<SessionId> = Vec::with_capacity(sessions);
    let mut seed_rng = Rng::new(0x09E4);
    let t0 = Instant::now();
    for rank in 0..sessions {
        let p = &profs[rank % profs.len()];
        let points = rows_for(p.prec, seed_rng.normal_vec(2 * p.d, 0.3));
        let req = match p.window {
            Some(window) => {
                Request::OpenWindow { points, stream: 2, d: p.d, depth: p.depth, window }
            }
            None => Request::OpenStream { points, stream: 2, d: p.d, depth: p.depth },
        };
        let resp = coord.call(req)?;
        ids.push(resp.session.expect("open returned no session id"));
    }
    let wall = t0.elapsed().as_secs_f64();
    let p99 = p99_us(&coord, RequestKind::OpenWindow);
    println!(
        "{:<8} {:>10} {:>12} {:>12.0} {:>9.0}us",
        "open", sessions, fmt_secs(wall), sessions as f64 / wall, p99
    );
    phases.push(("open", sessions, wall, sessions as f64 / wall, p99));
    anyhow::ensure!(
        coord.metrics().snapshot().sessions_spilled > 0,
        "the open flood never hit the budget: no eviction churn to soak"
    );

    // Phase 2: the Zipf storm — hot ranks hammered, cold ranks touched
    // rarely (each such touch is a transparent reload), ragged chunks,
    // windowed sessions polled every fourth touch. Windowed feeds
    // coalesce into small `feed_batch` groups (the feed lane's flush
    // path), so the storm also soaks the lane-fused window-slide sweep;
    // plain feeds stay on the scalar `call` path and keep the Feed
    // latency histogram fed.
    fn flush_group(
        coord: &Coordinator,
        group: &mut Vec<(SessionId, Rows, usize)>,
    ) -> anyhow::Result<()> {
        for r in coord.sessions().feed_batch(std::mem::take(group)) {
            r?;
        }
        Ok(())
    }
    let mut wl = Workload::new(sessions, 1.1, 6, 0x5708);
    let t0 = Instant::now();
    let mut polls = 0usize;
    let mut group: Vec<(SessionId, Rows, usize)> = Vec::new();
    for e in 0..events {
        let ev = wl.next_event();
        let p = &profs[ev.session % profs.len()];
        let points = rows_for(p.prec, wl.rng().normal_vec(ev.points * p.d, 0.3));
        if p.window.is_some() {
            group.push((ids[ev.session], points, ev.points));
            if group.len() >= 8 {
                flush_group(&coord, &mut group)?;
            }
        } else {
            coord.call(Request::Feed { session: ids[ev.session], points, count: ev.points })?;
        }
        if p.window.is_some() && e % 4 == 0 {
            coord.call(Request::PollWindow { session: ids[ev.session], max_slides: None })?;
            polls += 1;
        }
    }
    flush_group(&coord, &mut group)?;
    let wall = t0.elapsed().as_secs_f64();
    let p99 = p99_us(&coord, RequestKind::Feed);
    println!(
        "{:<8} {:>10} {:>12} {:>12.0} {:>9.0}us",
        "storm", events + polls, fmt_secs(wall), (events + polls) as f64 / wall, p99
    );
    phases.push(("storm", events + polls, wall, (events + polls) as f64 / wall, p99));
    let snap = coord.metrics().snapshot();
    anyhow::ensure!(snap.sessions_reloaded > 0, "Zipf storm never reloaded a cold session");
    anyhow::ensure!(snap.errors == 0, "storm produced {} request errors", snap.errors);
    anyhow::ensure!(
        snap.window_slide_batches > 0,
        "the storm never engaged the lane-fused window sweep"
    );

    // Phase 3: drain every windowed session once.
    let t0 = Instant::now();
    let mut drains = 0usize;
    for (rank, &id) in ids.iter().enumerate() {
        if profs[rank % profs.len()].window.is_some() {
            coord.call(Request::PollWindow { session: id, max_slides: None })?;
            drains += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let p99 = p99_us(&coord, RequestKind::PollWindow);
    println!(
        "{:<8} {:>10} {:>12} {:>12.0} {:>9.0}us",
        "drain", drains, fmt_secs(wall), drains as f64 / wall, p99
    );
    phases.push(("drain", drains, wall, drains as f64 / wall, p99));
    let snap = coord.metrics().snapshot();
    anyhow::ensure!(snap.window_slides > 0, "the soak emitted no window slides at all");
    println!(
        "soak: {} slides across {} polls ({} batched via {} lane-fused sweeps), \
         spilled={} reloaded={}",
        snap.window_slides,
        snap.window_polls,
        snap.window_slides_batched,
        snap.window_slide_batches,
        snap.sessions_spilled,
        snap.sessions_reloaded
    );

    if !check {
        // The SLO gate the latency histograms exist for: p99 of the two
        // hot-path kinds stays under 20 ms even through reload churn
        // (log2 upper edges overestimate, so this is conservative).
        let slo = Duration::from_millis(20);
        for kind in [RequestKind::Feed, RequestKind::PollWindow] {
            let p99 = coord.metrics().latency_of(kind).quantile(0.99);
            anyhow::ensure!(
                p99 <= slo,
                "p99 {} latency {p99:?} breaches the {slo:?} SLO",
                kind.label()
            );
        }
    }

    let json = soak_json(hw, sessions, check, &phases, &speedups, &memory);
    std::fs::write("BENCH_soak.json", &json)?;
    println!("\nwrote BENCH_soak.json");
    if check {
        // Structural smoke: the artifact parses and carries every
        // section; the bitwise/memory/churn gates above are the real
        // assertions.
        let parsed = signax::substrate::json::Json::parse(&json)?;
        for section in ["phases", "speedup", "memory"] {
            let arr = parsed
                .get(section)
                .and_then(|p| p.as_arr())
                .ok_or_else(|| anyhow::anyhow!("BENCH_soak.json has no {section}[]"))?;
            anyhow::ensure!(!arr.is_empty(), "BENCH_soak.json {section}[] is empty");
        }
        for phase in ["open", "storm", "drain"] {
            anyhow::ensure!(
                parsed.get("phases").and_then(|p| p.as_arr()).unwrap().iter().any(|p| {
                    p.get("phase").and_then(|v| v.as_str()).is_some_and(|s| s == phase)
                }),
                "phase {phase} missing from BENCH_soak.json"
            );
        }
        println!("check: all sections present, gates passed");
    }
    Ok(())
}
