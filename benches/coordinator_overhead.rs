//! Coordinator overhead benchmark: native direct call vs routed through
//! the coordinator (native backend) vs routed through the batcher to XLA.
//! The DESIGN.md target: the coordinator adds <5% latency over a direct
//! native call at batch-32 style workloads.

use std::time::Instant;

use signax::coordinator::{Coordinator, CoordinatorConfig, Request};
use signax::signature::signature;
use signax::substrate::benchlib::{bench, black_box, fmt_secs, BenchConfig};
use signax::substrate::rng::Rng;
use signax::ta::SigSpec;

fn main() -> anyhow::Result<()> {
    let cfg = BenchConfig {
        warmup: 2,
        repeats: 20,
        budget: std::time::Duration::from_secs(5),
        min_repeats: 3,
    };
    let (stream, d, depth) = (128usize, 4usize, 4usize);
    let spec = SigSpec::new(d, depth)?;
    let mut rng = Rng::new(5);
    let path = signax::data::random_path(&mut rng, stream, d, 0.2);

    // Direct native call.
    let direct = bench(&cfg, || {
        black_box(signature(&path, stream, &spec));
    })
    .best_secs();

    // Through the coordinator, native routing. Microbatching is disabled
    // for this serial measurement (the documented native_batch = 0 escape
    // hatch, preserved through the planner): a lone caller would
    // otherwise just be timing the batcher linger, not the routing
    // overhead.
    let coord = Coordinator::new(CoordinatorConfig::native_only().with_native_batch(0))?;
    let routed = bench(&cfg, || {
        let r = coord
            .call(Request::Signature { path: path.clone().into(), stream, d, depth })
            .unwrap();
        black_box(r.values.as_f32().unwrap()[0]);
    })
    .best_secs();

    println!("direct native:        {}", fmt_secs(direct));
    println!(
        "coordinator (native): {}  (+{:.1}% overhead)",
        fmt_secs(routed),
        (routed / direct - 1.0) * 100.0
    );

    // Concurrent native traffic with microbatching on (the default): 32
    // same-spec callers coalesce into lane-fused sweeps.
    let coord = Coordinator::new(CoordinatorConfig::native_only())?;
    let t0 = Instant::now();
    let reps = 5;
    for _ in 0..reps {
        let reqs: Vec<Request> = (0..32)
            .map(|_| Request::Signature { path: path.clone().into(), stream, d, depth })
            .collect();
        for r in coord.call_many(reqs) {
            r?;
        }
    }
    let per_req = t0.elapsed().as_secs_f64() / (32.0 * reps as f64);
    println!(
        "coordinator (native, 32 concurrent, lane-fused microbatches): {} per request",
        fmt_secs(per_req)
    );
    println!("native batcher metrics: {}", coord.metrics().snapshot().render());

    // Through the batcher to XLA, 32 concurrent requests (amortised).
    let coord = Coordinator::new(CoordinatorConfig::default())?;
    if coord.has_xla() {
        // warm
        let _ = coord.call(Request::Signature { path: path.clone().into(), stream, d, depth });
        let t0 = Instant::now();
        let reps = 5;
        for _ in 0..reps {
            let reqs: Vec<Request> = (0..32)
                .map(|_| Request::Signature { path: path.clone().into(), stream, d, depth })
                .collect();
            for r in coord.call_many(reqs) {
                r.unwrap();
            }
        }
        let per_req = t0.elapsed().as_secs_f64() / (32.0 * reps as f64);
        println!("coordinator (XLA, 32 concurrent): {} per request", fmt_secs(per_req));
        println!("batcher metrics: {}", coord.metrics().snapshot().render());
    } else {
        println!("(XLA column skipped: no artifacts)");
    }
    Ok(())
}
