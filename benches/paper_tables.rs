//! `cargo bench` entry: regenerates a CI-scale cut of every paper table.
//! For the full paper-scale sweep use:
//!
//!     cargo run --release -- tables --scale paper

use signax::bench::{run_table, table_ids, BenchCtx, Scale};

fn main() {
    let ctx = BenchCtx::new(Scale::Ci, Some("artifacts".into()));
    for id in table_ids() {
        match run_table(&ctx, id) {
            Ok(t) => println!("{}", t.render()),
            Err(e) => eprintln!("table {id}: {e}"),
        }
    }
}
