//! Session-persistence benchmark: what durable sessions cost and what a
//! restart buys back. Three phases over the `state` layer under the
//! session table:
//!
//! - `churn`: feed throughput with the resident budget set to half the
//!   fleet, so every round spills idle sessions and reloads touched ones
//!   (the steady state of an over-subscribed serving box).
//! - `touch_resident` / `touch_reload`: interval-query latency on a
//!   resident session vs one that must reload from the spill store
//!   first — the price of a cold touch.
//! - `recovery`: warm-restart wall time vs session count — open a fleet
//!   against a disk state dir, drop the manager, time
//!   `SessionManager::with_config` replaying the feed log.
//!
//!     cargo bench --bench session_persistence             # -> BENCH_persist.json
//!     cargo bench --bench session_persistence -- --check  # CI smoke: reduced
//!         counts; the bitwise gates (spill -> touch -> reload in f32 and
//!         f64, restart vs unrestarted control) plus JSON well-formedness
//!         are the assertions — timing-free, so CI noise cannot flake it.
//!
//! Every phase runs behind the bitwise gate: a spilled-and-reloaded
//! session must answer queries, signatures, and post-reload feeds with
//! exactly the bits of a never-spilled control.

use std::sync::Arc;
use std::time::Instant;

use signax::bench::persist_json;
use signax::coordinator::{Metrics, SessionConfig, SessionManager};
use signax::path::Path;
use signax::state::SpillConfig;
use signax::substrate::benchlib::fmt_secs;
use signax::substrate::pool::default_threads;
use signax::substrate::rng::Rng;
use signax::ta::{Precision, Rows, SigSpec};

const D: usize = 3;
const DEPTH: usize = 4;
const SEED_POINTS: usize = 8;
const FEED_POINTS: usize = 16;

fn spec() -> SigSpec {
    SigSpec::new(D, DEPTH).unwrap()
}

/// Resident bytes of one bench-shaped session (measured, not hard-coded).
fn per_session_bytes() -> usize {
    let s = spec();
    Path::new(&s, &vec![0.0f32; SEED_POINTS * D], SEED_POINTS).unwrap().storage_bytes()
}

fn manager(budget: Option<usize>, spill: SpillConfig) -> SessionManager {
    SessionManager::with_config(
        Arc::new(Metrics::default()),
        SessionConfig { budget_bytes: budget, spill, ..SessionConfig::default() },
    )
    .unwrap()
}

/// The gate every timed phase rides on: spill -> touch -> reload must be
/// bitwise invisible, in both element precisions.
fn bitwise_gate() -> anyhow::Result<()> {
    let s = spec();
    let per = per_session_bytes();
    // f32, through the session table: budget for ~1.5 sessions, so the
    // second open spills the first; every touch below is a reload.
    let mgr = manager(Some(per + per / 2), SpillConfig::Memory);
    let control = manager(None, SpillConfig::None);
    let mut rng = Rng::new(0x9E57);
    let seed_a = rng.normal_vec(SEED_POINTS * D, 0.3);
    let seed_b = rng.normal_vec(SEED_POINTS * D, 0.3);
    let a = mgr.open(&s, &seed_a.clone().into(), SEED_POINTS)?;
    let ca = control.open(&s, &seed_a.clone().into(), SEED_POINTS)?;
    let b = mgr.open(&s, &seed_b.clone().into(), SEED_POINTS)?;
    let cb = control.open(&s, &seed_b.clone().into(), SEED_POINTS)?;
    let extra = rng.normal_vec(FEED_POINTS * D, 0.3);
    let ex: Rows = extra.clone().into();
    // Touch a (reload), then b (reload, spills a), then feed a after its
    // second reload; all three must match the never-spilled control.
    anyhow::ensure!(
        mgr.query(a, 1, SEED_POINTS - 1)? == control.query(ca, 1, SEED_POINTS - 1)?,
        "reloaded query diverged from control"
    );
    anyhow::ensure!(
        mgr.signature(b)? == control.signature(cb)?,
        "reloaded signature diverged from control"
    );
    anyhow::ensure!(
        mgr.feed(a, &ex, FEED_POINTS)? == control.feed(ca, &ex, FEED_POINTS)?,
        "feed after reload diverged from control"
    );
    // f64, through the same session table (rows stay natively typed end
    // to end, so f64 sessions spill, reload, and feed through f64
    // kernels): budget admits ~1.5 f64 sessions, every touch below is a
    // reload, and each must match a never-spilled f64 control bitwise.
    let s64 = SigSpec::with_dtype(D, DEPTH, Precision::F64)?;
    let per64 =
        Path::<f64>::new(&s64, &vec![0.0f64; SEED_POINTS * D], SEED_POINTS)?.storage_bytes();
    let mgr64 = manager(Some(per64 + per64 / 2), SpillConfig::Memory);
    let control64 = manager(None, SpillConfig::None);
    let widen =
        |v: &[f32]| -> Rows { v.iter().copied().map(f64::from).collect::<Vec<f64>>().into() };
    let (wa, wb, wx) = (widen(&seed_a), widen(&seed_b), widen(&extra));
    let a64 = mgr64.open(&s64, &wa, SEED_POINTS)?;
    let ca64 = control64.open(&s64, &wa, SEED_POINTS)?;
    let b64 = mgr64.open(&s64, &wb, SEED_POINTS)?;
    let cb64 = control64.open(&s64, &wb, SEED_POINTS)?;
    anyhow::ensure!(
        mgr64.query(a64, 1, SEED_POINTS - 1)? == control64.query(ca64, 1, SEED_POINTS - 1)?,
        "f64 reloaded query diverged from control"
    );
    anyhow::ensure!(
        mgr64.signature(b64)? == control64.signature(cb64)?,
        "f64 reloaded signature diverged from control"
    );
    anyhow::ensure!(
        mgr64.feed(a64, &wx, FEED_POINTS)? == control64.feed(ca64, &wx, FEED_POINTS)?,
        "f64 feed after reload diverged from control"
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let check = std::env::args().any(|a| a == "--check");
    let hw = default_threads();
    bitwise_gate()?;
    println!("bitwise gate: spill -> touch -> reload identical in f32 and f64");
    println!("{:<16} {:>9} {:>12} {:>12}", "phase", "sessions", "wall", "ops/s");
    let mut records: Vec<(&str, usize, f64, f64)> = vec![];
    let s = spec();
    let per = per_session_bytes();

    // Phase 1: spill/reload churn under budget pressure. Budget admits
    // half the fleet, feeds walk the fleet round-robin, so every feed of
    // a spilled session reloads it and pushes another out.
    let fleet = if check { 8 } else { 32 };
    let rounds = if check { 6 } else { 40 };
    {
        let mgr = manager(Some(per * fleet / 2), SpillConfig::Memory);
        let mut rng = Rng::new(0xC4);
        let ids: Vec<_> = (0..fleet)
            .map(|_| mgr.open(&s, &rng.normal_vec(SEED_POINTS * D, 0.3).into(), SEED_POINTS))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let t0 = Instant::now();
        let mut feeds = 0usize;
        for _ in 0..rounds {
            for &id in &ids {
                mgr.feed(id, &rng.normal_vec(FEED_POINTS * D, 0.3).into(), FEED_POINTS)?;
                feeds += 1;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        anyhow::ensure!(mgr.spilled_bytes() > 0, "budget pressure never spilled anything");
        let rate = feeds as f64 / wall;
        println!("{:<16} {:>9} {:>12} {:>12.0}", "churn", fleet, fmt_secs(wall), rate);
        records.push(("churn", fleet, wall, rate));
    }

    // Phase 2: cost of a cold touch. Resident baseline: one unbounded
    // manager, repeated queries. Reload series: budget for one session,
    // two sessions, alternating queries — every touch reloads.
    let touches = if check { 20 } else { 400 };
    {
        let mgr = manager(None, SpillConfig::None);
        let mut rng = Rng::new(0x70);
        let id = mgr.open(&s, &rng.normal_vec(SEED_POINTS * D, 0.3).into(), SEED_POINTS)?;
        let t0 = Instant::now();
        for _ in 0..touches {
            mgr.query(id, 1, SEED_POINTS - 1)?;
        }
        let wall = t0.elapsed().as_secs_f64();
        let rate = touches as f64 / wall;
        println!("{:<16} {:>9} {:>12} {:>12.0}", "touch_resident", 1, fmt_secs(wall), rate);
        records.push(("touch_resident", 1, wall, rate));
    }
    {
        let mgr = manager(Some(per + per / 2), SpillConfig::Memory);
        let mut rng = Rng::new(0x71);
        let a = mgr.open(&s, &rng.normal_vec(SEED_POINTS * D, 0.3).into(), SEED_POINTS)?;
        let b = mgr.open(&s, &rng.normal_vec(SEED_POINTS * D, 0.3).into(), SEED_POINTS)?;
        let t0 = Instant::now();
        for k in 0..touches {
            mgr.query(if k % 2 == 0 { a } else { b }, 1, SEED_POINTS - 1)?;
        }
        let wall = t0.elapsed().as_secs_f64();
        let rate = touches as f64 / wall;
        println!("{:<16} {:>9} {:>12} {:>12.0}", "touch_reload", 2, fmt_secs(wall), rate);
        records.push(("touch_reload", 2, wall, rate));
    }

    // Phase 3: warm-restart recovery wall time vs session count, against
    // a disk state dir. The restarted manager must answer bitwise like
    // the control captured before the drop.
    let axis: &[usize] = if check { &[4, 8] } else { &[4, 16, 64] };
    let state_root = std::env::temp_dir().join(format!(
        "signax-bench-persist-{}",
        std::process::id()
    ));
    for &n in axis {
        let dir = state_root.join(format!("n{n}"));
        let mut want = Vec::with_capacity(n);
        {
            let mgr = manager(None, SpillConfig::Disk(dir.clone()));
            let mut rng = Rng::new(0xD15C);
            let ids: Vec<_> = (0..n)
                .map(|_| mgr.open(&s, &rng.normal_vec(SEED_POINTS * D, 0.3).into(), SEED_POINTS))
                .collect::<anyhow::Result<Vec<_>>>()?;
            for &id in &ids {
                mgr.feed(id, &rng.normal_vec(FEED_POINTS * D, 0.3).into(), FEED_POINTS)?;
            }
            for &id in &ids {
                want.push((id, mgr.signature(id)?));
            }
            // Drop flushes the feed log.
        }
        let t0 = Instant::now();
        let mgr = manager(None, SpillConfig::Disk(dir.clone()));
        let wall = t0.elapsed().as_secs_f64();
        anyhow::ensure!(mgr.open_count() == n, "recovery lost sessions");
        for (id, sig) in &want {
            anyhow::ensure!(
                &mgr.signature(*id)? == sig,
                "restart diverged from the unrestarted control"
            );
        }
        let rate = n as f64 / wall;
        println!("{:<16} {:>9} {:>12} {:>12.0}", "recovery", n, fmt_secs(wall), rate);
        records.push(("recovery", n, wall, rate));
    }
    let _ = std::fs::remove_dir_all(&state_root);

    let json = persist_json(hw, &records);
    std::fs::write("BENCH_persist.json", &json)?;
    println!("\nwrote BENCH_persist.json");
    if check {
        // Structural smoke (timing-free): the artifact parses and covers
        // every phase; the bitwise gates above are the real assertions.
        let parsed = signax::substrate::json::Json::parse(&json)?;
        let pts = parsed
            .get("points")
            .and_then(|p| p.as_arr())
            .ok_or_else(|| anyhow::anyhow!("BENCH_persist.json has no points[]"))?;
        for phase in ["churn", "touch_resident", "touch_reload", "recovery"] {
            anyhow::ensure!(
                pts.iter().any(|p| {
                    p.get("phase").and_then(|v| v.as_str()).is_some_and(|s| s == phase)
                }),
                "phase {phase} missing from BENCH_persist.json"
            );
        }
        println!("check: all phases present, gates passed");
    }
    Ok(())
}
