//! Backward-pass scaling benchmark: serial reverse sweep vs the chunked
//! Chen-identity stream-parallel backward (`signature::backward`), swept
//! over stream lengths and thread counts. Writes the machine-readable
//! record the perf trajectory tracks:
//!
//!     cargo bench --bench backward_scaling        # -> BENCH_backward.json
//!
//! Acceptance target: >= 2x speedup at 8 threads on streams >= 2048
//! increments (channels=4, depth=4).

use signax::bench::backward_json;
use signax::signature::{signature_vjp, signature_vjp_with, SigConfig};
use signax::substrate::benchlib::{bench, black_box, fmt_secs, BenchConfig};
use signax::substrate::pool::default_threads;
use signax::substrate::rng::Rng;
use signax::ta::SigSpec;

fn main() -> anyhow::Result<()> {
    let cfg = BenchConfig {
        warmup: 1,
        repeats: 20,
        budget: std::time::Duration::from_secs(8),
        min_repeats: 3,
    };
    let spec = SigSpec::new(4, 4)?;
    let streams = [512usize, 2048, 8192];
    let hw = default_threads();
    let mut thread_axis: Vec<usize> = [2usize, 4, 8]
        .into_iter()
        .filter(|&t| t <= hw.max(2))
        .collect();
    if thread_axis.is_empty() {
        thread_axis.push(2);
    }
    // No silent caps: the acceptance point is 8 threads, so say so when
    // the machine cannot measure it (e.g. 4-vCPU CI runners).
    for &t in &[2usize, 4, 8] {
        if !thread_axis.contains(&t) {
            eprintln!(
                "note: skipping {t}-thread series (machine has {hw} hardware threads); \
                 the >=2x-at-8-threads acceptance point is not measurable here"
            );
        }
    }
    println!(
        "{:<8} {:>12} {:>4}  {:>12} {:>8}",
        "stream", "serial", "T", "parallel", "speedup"
    );

    // One record per (stream, threads) point, written through the same
    // emitter as bench::tables' backward table so both producers share
    // one BENCH_backward.json schema.
    let mut records = vec![];
    for &stream in &streams {
        let mut rng = Rng::new(stream as u64 ^ 0xBAC);
        let path = signax::data::random_path(&mut rng, stream, 4, 0.1);
        let cot = rng.normal_vec(spec.sig_len(), 1.0);
        let serial = bench(&cfg, || {
            black_box(signature_vjp(&path, stream, &spec, &cot));
        })
        .best_secs();
        for &t in &thread_axis {
            let pcfg = SigConfig::parallel(t);
            let parallel = bench(&cfg, || {
                black_box(
                    signature_vjp_with(&path, stream, &spec, &pcfg, &cot)
                        .unwrap()
                        .grad_path,
                );
            })
            .best_secs();
            println!(
                "{:<8} {:>12} {:>4}  {:>12} {:>7.2}x",
                stream,
                fmt_secs(serial),
                t,
                fmt_secs(parallel),
                serial / parallel
            );
            records.push((stream, t, serial, parallel));
        }
    }
    std::fs::write("BENCH_backward.json", backward_json(hw, &records))?;
    println!("\nwrote BENCH_backward.json");
    Ok(())
}
