//! Batch-lane engine benchmark: lane-fused forward/backward throughput vs
//! per-path dispatch, swept over lane counts L ∈ {1, 4, 8, 16} and
//! channels d ∈ {2, 4, 8} at depth 4 over short streams — the serving
//! regime where one-thread-per-path leaves the SIMD lanes idle. Both
//! sides run single-threaded so the speedup isolates lane utilisation,
//! not thread scaling. Writes the machine-readable record the perf
//! trajectory tracks:
//!
//!     cargo bench --bench batch_lanes             # -> BENCH_batch.json
//!     cargo bench --bench batch_lanes -- --check  # CI smoke: reduced
//!         iteration count plus a hard speedup assertion, so kernel
//!         regressions fail CI instead of only skewing uploaded artifacts
//!
//! Acceptance target: >= 2x forward throughput over per-path dispatch at
//! L = 16, d = 2 (recorded in BENCH_batch.json). Every timed point is
//! first gated on bitwise equality between the lane-fused rows and
//! per-path dispatch.

use signax::bench::batch_json;
use signax::signature::{signature, signature_batch, signature_batch_vjp, signature_vjp};
use signax::substrate::benchlib::{bench, black_box, fmt_secs, BenchConfig};
use signax::substrate::pool::default_threads;
use signax::substrate::rng::Rng;
use signax::ta::SigSpec;

const DEPTH: usize = 4;
const STREAM: usize = 32;

fn main() -> anyhow::Result<()> {
    let check = std::env::args().any(|a| a == "--check");
    let cfg = if check {
        // Smoke protocol: reduced but not tiny — best-of-20 (min time)
        // rides out noisy-neighbor spikes on shared CI runners while the
        // 1.2x floor leaves headroom below the >= 2x full-run target, so
        // only a genuine kernel regression trips the gate.
        BenchConfig {
            warmup: 2,
            repeats: 20,
            budget: std::time::Duration::from_secs(4),
            min_repeats: 5,
        }
    } else {
        BenchConfig {
            warmup: 1,
            repeats: 30,
            budget: std::time::Duration::from_secs(6),
            min_repeats: 3,
        }
    };
    println!(
        "{:<9} {:>3} {:>4} {:>12} {:>12} {:>8}",
        "op", "d", "L", "per-path", "lane-fused", "speedup"
    );
    let mut records: Vec<(&str, usize, usize, usize, f64, f64)> = vec![];
    for &d in &[2usize, 4, 8] {
        let spec = SigSpec::new(d, DEPTH)?;
        let len = spec.sig_len();
        for &lanes in &[1usize, 4, 8, 16] {
            let mut rng = Rng::new(0xBA7C ^ ((d as u64) << 8) ^ lanes as u64);
            let paths = signax::data::random_batch(&mut rng, lanes, STREAM, d, 0.2);
            let plen = STREAM * d;
            // Correctness gate before timing: lane-fused == per-path,
            // bitwise, forward and backward.
            let batched = signature_batch(&paths, lanes, STREAM, &spec, 1)?;
            let cots = rng.normal_vec(lanes * len, 1.0);
            let batched_grad = signature_batch_vjp(&paths, lanes, STREAM, &spec, &cots, 1)?;
            for l in 0..lanes {
                let single = signature(&paths[l * plen..(l + 1) * plen], STREAM, &spec);
                anyhow::ensure!(
                    batched[l * len..(l + 1) * len] == single[..],
                    "forward lane {l} of d={d} L={lanes} diverged from per-path dispatch"
                );
                let single_grad = signature_vjp(
                    &paths[l * plen..(l + 1) * plen],
                    STREAM,
                    &spec,
                    &cots[l * len..(l + 1) * len],
                );
                anyhow::ensure!(
                    batched_grad[l * plen..(l + 1) * plen] == single_grad[..],
                    "backward lane {l} of d={d} L={lanes} diverged from per-path dispatch"
                );
            }
            let fwd_per_path = bench(&cfg, || {
                for b in 0..lanes {
                    black_box(signature(&paths[b * plen..(b + 1) * plen], STREAM, &spec));
                }
            })
            .best_secs();
            let fwd_lane = bench(&cfg, || {
                black_box(signature_batch(&paths, lanes, STREAM, &spec, 1).unwrap());
            })
            .best_secs();
            println!(
                "{:<9} {:>3} {:>4} {:>12} {:>12} {:>7.2}x",
                "forward",
                d,
                lanes,
                fmt_secs(fwd_per_path),
                fmt_secs(fwd_lane),
                fwd_per_path / fwd_lane
            );
            records.push(("forward", d, lanes, STREAM, fwd_per_path, fwd_lane));
            let bwd_per_path = bench(&cfg, || {
                for b in 0..lanes {
                    black_box(signature_vjp(
                        &paths[b * plen..(b + 1) * plen],
                        STREAM,
                        &spec,
                        &cots[b * len..(b + 1) * len],
                    ));
                }
            })
            .best_secs();
            let bwd_lane = bench(&cfg, || {
                black_box(signature_batch_vjp(&paths, lanes, STREAM, &spec, &cots, 1).unwrap());
            })
            .best_secs();
            println!(
                "{:<9} {:>3} {:>4} {:>12} {:>12} {:>7.2}x",
                "backward",
                d,
                lanes,
                fmt_secs(bwd_per_path),
                fmt_secs(bwd_lane),
                bwd_per_path / bwd_lane
            );
            records.push(("backward", d, lanes, STREAM, bwd_per_path, bwd_lane));
        }
    }
    std::fs::write("BENCH_batch.json", batch_json(default_threads(), DEPTH, &records))?;
    println!("\nwrote BENCH_batch.json");
    if check {
        // Hard gate at the acceptance point (with headroom for CI-runner
        // noise: the recorded full-run target is >= 2x).
        let &(_, _, _, _, per_path, lane) = records
            .iter()
            .find(|r| r.0 == "forward" && r.1 == 2 && r.2 == 16)
            .expect("acceptance point measured");
        let speedup = per_path / lane;
        anyhow::ensure!(
            speedup >= 1.2,
            "batch-lane smoke FAILED: forward speedup at d=2, L=16 is {speedup:.2}x \
             (smoke floor 1.2x; full-run acceptance >= 2x)"
        );
        println!("smoke ok: forward speedup at d=2, L=16 = {speedup:.2}x");
    }
    Ok(())
}
