//! Batch-lane engine benchmark: lane-fused forward/backward throughput vs
//! per-path dispatch, swept over lane counts L ∈ {1, 4, 8, 16} and
//! channels d ∈ {2, 4, 8} at depth 4 over short streams — the serving
//! regime where one-thread-per-path leaves the SIMD lanes idle — plus a
//! beyond-the-mono-window sweep at d ∈ {12, 20} in **both precisions**
//! (f32 and f64), which exercises the runtime-`d` kernels the dispatch
//! falls to past `LANE_VJP_MAX_D`, and a **per-width sweep** over the
//! planner's runtime lane tiers W ∈ `exec::LANE_WIDTHS` (one full block
//! per width, executed under an explicit `LaneFused` plan, bitwise-gated
//! against per-path dispatch) — the evidence behind the planner's
//! `lane_width` choice. Both sides run single-threaded so the
//! speedup isolates lane utilisation, not thread scaling. A final
//! mono-vs-dyn section times one fused multiply-exponentiate VJP step
//! per `d` with the const-`D` dispatch against the runtime-`d` body, so
//! the `d <= 8` crossover stays benchmark-arbitrated rather than
//! asserted (`bench::mono_dyn_crossover` reads those records back as
//! the retirement evidence). Writes the machine-readable record the
//! perf trajectory tracks:
//!
//!     cargo bench --bench batch_lanes             # -> BENCH_batch.json
//!     cargo bench --bench batch_lanes -- --check  # CI smoke: reduced
//!         iteration count plus a hard speedup assertion, so kernel
//!         regressions fail CI instead of only skewing uploaded artifacts
//!
//! Acceptance target: >= 2x forward throughput over per-path dispatch at
//! L = 16, d = 2 in f32 (recorded in BENCH_batch.json). Every timed point
//! is first gated on bitwise equality between the lane-fused rows and
//! per-path dispatch — in the point's own precision.

use signax::bench::{batch_json, mono_dyn_crossover};
use signax::exec::{ExecPlan, LANE_WIDTHS};
use signax::signature::{
    signature, signature_batch, signature_batch_planned, signature_batch_vjp, signature_vjp,
    SigConfig,
};
use signax::substrate::benchlib::{bench, black_box, fmt_secs, BenchConfig};
use signax::substrate::pool::default_threads;
use signax::substrate::rng::Rng;
use signax::ta::fused::{fused_mexp_vjp, fused_mexp_vjp_dyn};
use signax::ta::{Elem, SigSpec, Workspace};

const DEPTH: usize = 4;
/// Depth of the beyond-the-mono-window sweep (d = 12, 20): one level
/// shallower so the d = 20 tensor algebra stays inside the bench budget.
const WIDE_DEPTH: usize = 3;
/// Lane count of the wide sweep — the serving block size.
const WIDE_LANES: usize = 16;
const STREAM: usize = 32;

/// `(op, prec, d, depth, lanes, stream, per_path_s, lane_s)` — the
/// [`batch_json`] point format.
type Record = (&'static str, &'static str, usize, usize, usize, usize, f64, f64);

/// One (prec, d, lanes) cell: bitwise-gate the lane engine against
/// per-path dispatch in `E`, then time both sides, forward and backward.
fn sweep_lanes<E: Elem>(
    cfg: &BenchConfig,
    prec: &'static str,
    d: usize,
    depth: usize,
    lanes: usize,
    records: &mut Vec<Record>,
) -> anyhow::Result<()> {
    let spec = SigSpec::new(d, depth)?;
    let len = spec.sig_len();
    let plen = STREAM * d;
    let mut rng = Rng::new(0xBA7C ^ ((d as u64) << 8) ^ lanes as u64);
    let paths: Vec<E> = signax::data::random_batch(&mut rng, lanes, STREAM, d, 0.2)
        .into_iter()
        .map(E::from_f32)
        .collect();
    let cots: Vec<E> =
        rng.normal_vec(lanes * len, 1.0).into_iter().map(E::from_f32).collect();
    // Correctness gate before timing: lane-fused == per-path, bitwise,
    // forward and backward. Past d = 8 the backward side runs the
    // runtime-`d` VJP body, so this is also the dyn-kernel parity gate.
    let batched = signature_batch(&paths, lanes, STREAM, &spec, 1)?;
    let batched_grad = signature_batch_vjp(&paths, lanes, STREAM, &spec, &cots, 1)?;
    for l in 0..lanes {
        let single = signature(&paths[l * plen..(l + 1) * plen], STREAM, &spec);
        anyhow::ensure!(
            batched[l * len..(l + 1) * len] == single[..],
            "forward lane {l} of {prec} d={d} L={lanes} diverged from per-path dispatch"
        );
        let single_grad = signature_vjp(
            &paths[l * plen..(l + 1) * plen],
            STREAM,
            &spec,
            &cots[l * len..(l + 1) * len],
        );
        anyhow::ensure!(
            batched_grad[l * plen..(l + 1) * plen] == single_grad[..],
            "backward lane {l} of {prec} d={d} L={lanes} diverged from per-path dispatch"
        );
    }
    let fwd_per_path = bench(cfg, || {
        for b in 0..lanes {
            black_box(signature(&paths[b * plen..(b + 1) * plen], STREAM, &spec));
        }
    })
    .best_secs();
    let fwd_lane = bench(cfg, || {
        black_box(signature_batch(&paths, lanes, STREAM, &spec, 1).unwrap());
    })
    .best_secs();
    println!(
        "{:<9} {:>4} {:>3} {:>4} {:>12} {:>12} {:>7.2}x",
        "forward",
        prec,
        d,
        lanes,
        fmt_secs(fwd_per_path),
        fmt_secs(fwd_lane),
        fwd_per_path / fwd_lane
    );
    records.push(("forward", prec, d, depth, lanes, STREAM, fwd_per_path, fwd_lane));
    let bwd_per_path = bench(cfg, || {
        for b in 0..lanes {
            black_box(signature_vjp(
                &paths[b * plen..(b + 1) * plen],
                STREAM,
                &spec,
                &cots[b * len..(b + 1) * len],
            ));
        }
    })
    .best_secs();
    let bwd_lane = bench(cfg, || {
        black_box(signature_batch_vjp(&paths, lanes, STREAM, &spec, &cots, 1).unwrap());
    })
    .best_secs();
    println!(
        "{:<9} {:>4} {:>3} {:>4} {:>12} {:>12} {:>7.2}x",
        "backward",
        prec,
        d,
        lanes,
        fmt_secs(bwd_per_path),
        fmt_secs(bwd_lane),
        bwd_per_path / bwd_lane
    );
    records.push(("backward", prec, d, depth, lanes, STREAM, bwd_per_path, bwd_lane));
    Ok(())
}

/// Per-width sweep over the planner's runtime lane tiers: one full block
/// of `W` lanes per width, executed under an explicit
/// `LaneFused { block: W }` plan so the recorded point isolates the
/// width itself (the planner would otherwise re-choose it). Each point
/// is bitwise-gated against per-path dispatch first — wide blocks are a
/// schedule, never a value change.
fn sweep_widths(cfg: &BenchConfig, d: usize, records: &mut Vec<Record>) -> anyhow::Result<()> {
    let spec = SigSpec::new(d, DEPTH)?;
    let len = spec.sig_len();
    let plen = STREAM * d;
    let sig_cfg = SigConfig::serial();
    for &w in &LANE_WIDTHS {
        let mut rng = Rng::new(0x71DE ^ ((d as u64) << 8) ^ w as u64);
        let paths = signax::data::random_batch(&mut rng, w, STREAM, d, 0.2);
        let plan = ExecPlan::LaneFused { block: w };
        let batched = signature_batch_planned(&paths, w, STREAM, &spec, &sig_cfg, plan)?;
        for l in 0..w {
            let single = signature(&paths[l * plen..(l + 1) * plen], STREAM, &spec);
            anyhow::ensure!(
                batched[l * len..(l + 1) * len] == single[..],
                "width {w} lane {l} (d={d}) diverged from per-path dispatch"
            );
        }
        let per_path = bench(cfg, || {
            for b in 0..w {
                black_box(signature(&paths[b * plen..(b + 1) * plen], STREAM, &spec));
            }
        })
        .best_secs();
        let lane = bench(cfg, || {
            black_box(
                signature_batch_planned(&paths, w, STREAM, &spec, &sig_cfg, plan).unwrap(),
            );
        })
        .best_secs();
        println!(
            "{:<9} {:>4} {:>3} {:>4} {:>12} {:>12} {:>7.2}x",
            "width",
            "f32",
            d,
            w,
            fmt_secs(per_path),
            fmt_secs(lane),
            per_path / lane
        );
        records.push(("width", "f32", d, DEPTH, w, STREAM, per_path, lane));
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let check = std::env::args().any(|a| a == "--check");
    let cfg = if check {
        // Smoke protocol: reduced but not tiny — best-of-20 (min time)
        // rides out noisy-neighbor spikes on shared CI runners while the
        // 1.2x floor leaves headroom below the >= 2x full-run target, so
        // only a genuine kernel regression trips the gate.
        BenchConfig {
            warmup: 2,
            repeats: 20,
            budget: std::time::Duration::from_secs(4),
            min_repeats: 5,
        }
    } else {
        BenchConfig {
            warmup: 1,
            repeats: 30,
            budget: std::time::Duration::from_secs(6),
            min_repeats: 3,
        }
    };
    println!(
        "{:<9} {:>4} {:>3} {:>4} {:>12} {:>12} {:>8}",
        "op", "prec", "d", "L", "per-path", "lane-fused", "speedup"
    );
    let mut records: Vec<Record> = vec![];
    // The mono window: the const-D dispatch, f32, full lane sweep.
    for &d in &[2usize, 4, 8] {
        for &lanes in &[1usize, 4, 8, 16] {
            sweep_lanes::<f32>(&cfg, "f32", d, DEPTH, lanes, &mut records)?;
        }
    }
    // Beyond the mono window: runtime-`d` kernels, both precisions, at
    // the serving lane count.
    for &d in &[12usize, 20] {
        sweep_lanes::<f32>(&cfg, "f32", d, WIDE_DEPTH, WIDE_LANES, &mut records)?;
        sweep_lanes::<f64>(&cfg, "f64", d, WIDE_DEPTH, WIDE_LANES, &mut records)?;
    }
    // The planner's runtime lane tiers, one full block per width.
    for &d in &[2usize, 4] {
        sweep_widths(&cfg, d, &mut records)?;
    }
    // Mono-vs-dyn crossover: one fused multiply-exponentiate VJP step per
    // d — the const-D dispatch against the runtime-`d` body (identical op
    // order, so any gap is pure codegen). Past d = 8 both columns run the
    // dyn body and the ratio pins to ~1. Recorded so the d <= 8 crossover
    // stays benchmark-arbitrated: if dyn ever catches mono inside the
    // window, the mono bodies can be retired.
    println!(
        "\n{:<9} {:>4} {:>3} {:>12} {:>12} {:>8}",
        "op", "prec", "d", "mono", "dyn", "mono/dyn"
    );
    for &(d, depth) in
        &[(2usize, DEPTH), (4, DEPTH), (8, DEPTH), (12, WIDE_DEPTH), (20, WIDE_DEPTH)]
    {
        let spec = SigSpec::new(d, depth)?;
        let len = spec.sig_len();
        let mut rng = Rng::new(0xD1A6 ^ d as u64);
        let a = rng.normal_vec(len, 0.3);
        let z = rng.normal_vec(d, 0.3);
        let g = rng.normal_vec(len, 1.0);
        let mut ws = Workspace::new(&spec);
        let mut ga = vec![0.0f32; len];
        let mut gz = vec![0.0f32; d];
        let t_mono = bench(&cfg, || {
            ga.iter_mut().for_each(|v| *v = 0.0);
            gz.iter_mut().for_each(|v| *v = 0.0);
            fused_mexp_vjp(&spec, &a, &z, &g, &mut ga, &mut gz, &mut ws);
            black_box(ga[0]);
        })
        .best_secs();
        let t_dyn = bench(&cfg, || {
            ga.iter_mut().for_each(|v| *v = 0.0);
            gz.iter_mut().for_each(|v| *v = 0.0);
            fused_mexp_vjp_dyn(&spec, &a, &z, &g, &mut ga, &mut gz, &mut ws);
            black_box(ga[0]);
        })
        .best_secs();
        println!(
            "{:<9} {:>4} {:>3} {:>12} {:>12} {:>7.2}x",
            "vjp_step",
            "f32",
            d,
            fmt_secs(t_mono),
            fmt_secs(t_dyn),
            t_mono / t_dyn
        );
        records.push(("vjp_step", "f32", d, depth, 0, 0, t_mono, t_dyn));
    }
    let json = batch_json(default_threads(), &records);
    std::fs::write("BENCH_batch.json", &json)?;
    println!("\nwrote BENCH_batch.json");
    if check {
        // Hard gate at the acceptance point (with headroom for CI-runner
        // noise: the recorded full-run target is >= 2x).
        let &(.., per_path, lane) = records
            .iter()
            .find(|r| r.0 == "forward" && r.1 == "f32" && r.2 == 2 && r.4 == 16)
            .expect("acceptance point measured");
        let speedup = per_path / lane;
        anyhow::ensure!(
            speedup >= 1.2,
            "batch-lane smoke FAILED: forward speedup at d=2, L=16 is {speedup:.2}x \
             (smoke floor 1.2x; full-run acceptance >= 2x)"
        );
        println!("smoke ok: forward speedup at d=2, L=16 = {speedup:.2}x");
        // The mono-vs-dyn retirement evidence must read back through the
        // sanctioned helper: both sides of the window present, timings
        // positive — a schema drift fails here, not in offline tooling.
        let crossover = mono_dyn_crossover(&json)?;
        println!("smoke ok: {} mono-vs-dyn crossover records readable", crossover.len());
        // Every planner width tier was measured and bitwise-gated.
        for &w in &LANE_WIDTHS {
            anyhow::ensure!(
                records.iter().any(|r| r.0 == "width" && r.4 == w),
                "width sweep missing tier W={w}"
            );
        }
        println!("smoke ok: width sweep covers {LANE_WIDTHS:?}");
    }
    Ok(())
}
