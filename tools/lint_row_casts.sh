#!/usr/bin/env sh
# Row-cast lint for the serving layer.
#
# The data plane carries rows as natively typed `ta::Rows` from the wire
# to the kernels; the ONE place serving code may inspect the precision
# tag and pick an element type is `coordinator/rows.rs` (the `with_elem!`
# boundary). An `as f32` / `as f64` anywhere else in `coordinator/` is
# how a silent upcast sneaks back onto the f64 path, so this script
# fails CI on any new one.
#
# Escape hatch for genuinely non-row arithmetic (counters, ratios):
# append `// lint: non-row cast` to the offending line.
#
# Usage: tools/lint_row_casts.sh   (run from the repo root; exits 1 on
# violations, printing each offending line)

set -eu

cd "$(dirname "$0")/.."

violations=$(grep -rnE 'as f(32|64)\b' rust/src/coordinator --include='*.rs' \
    | grep -v '^rust/src/coordinator/rows\.rs:' \
    | grep -v 'lint: non-row cast' \
    || true)

if [ -n "$violations" ]; then
    echo "row-cast lint FAILED: 'as f32'/'as f64' outside the sanctioned" >&2
    echo "precision boundary (coordinator/rows.rs). Rows must stay natively" >&2
    echo "typed; convert via the Elem row hooks, or mark genuinely non-row" >&2
    echo "arithmetic with '// lint: non-row cast'." >&2
    echo "$violations" >&2
    exit 1
fi
echo "row-cast lint ok: no unsanctioned f32/f64 casts in coordinator/"
