"""AOT pipeline: lowering produces loadable HLO text, manifest and golden
files are well-formed and reproducible."""

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import numpy as np

from compile import aot
from compile.kernels import ref

REPO = Path(__file__).resolve().parents[2]
ARTIFACTS = REPO / "artifacts"


def test_lowering_emits_hlo_text():
    text = aot.to_hlo_text(aot.lower_signature(2, 8, 2, 2, use_pallas=True))
    assert text.startswith("HloModule"), text[:80]
    assert "while" in text or "fusion" in text or "dot" in text or "multiply" in text


def test_grad_lowering_emits_hlo_text():
    text = aot.to_hlo_text(aot.lower_signature_grad(1, 6, 2, 2))
    assert text.startswith("HloModule")


def test_manifest_consistent_with_files():
    manifest = ARTIFACTS / "MANIFEST.json"
    if not manifest.exists():
        import pytest

        pytest.skip("run `make artifacts` first")
    blob = json.loads(manifest.read_text())
    assert blob["artifacts"], "empty manifest"
    for entry in blob["artifacts"]:
        f = ARTIFACTS / entry["file"]
        assert f.exists(), f
        assert f.read_text(encoding="utf-8", errors="ignore").startswith("HloModule")
        assert entry["kind"] in {"sig", "siggrad", "logsig", "train"}
        if entry["kind"] == "sig":
            assert entry["out_dim"] == ref.sig_len(entry["d"], entry["depth"])
        if entry["kind"] == "logsig":
            assert entry["out_dim"] == ref.witt_dimension(entry["d"], entry["depth"])


def test_golden_files_reproducible():
    gdir = ARTIFACTS / "golden"
    if not gdir.exists():
        import pytest

        pytest.skip("run `make artifacts` first")
    files = sorted(gdir.glob("golden_*.json"))
    assert files
    import jax.numpy as jnp

    for f in files[:3]:
        blob = json.loads(f.read_text())
        d, depth, L = blob["d"], blob["depth"], blob["length"]
        path = np.asarray(blob["path"], np.float32).reshape(L, d)
        sig = ref.signature_ref(jnp.asarray(path)[None], depth)[0]
        np.testing.assert_allclose(
            np.asarray(sig), np.asarray(blob["sig"], np.float32), rtol=1e-5, atol=1e-6
        )
        assert len(blob["logsig_words"]) == ref.witt_dimension(d, depth)
        assert len(blob["grad_sum_sig"]) == L * d
