"""L2 correctness: model graphs, logsignature, and the train step."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref


def gbm_batch(rng, b, L, two_vols=True):
    """Geometric Brownian motion samples with one of two volatilities and a
    time channel — the §6.2 toy dataset."""
    dt = 1.0 / L
    vol = np.where(rng.integers(0, 2, size=b) == 1, 0.6, 0.2).astype(np.float32)
    y = (vol > 0.4).astype(np.float32)
    noise = rng.normal(size=(b, L)).astype(np.float32)
    logret = (-0.5 * vol[:, None] ** 2) * dt + vol[:, None] * np.sqrt(dt) * noise
    s = np.exp(np.cumsum(logret, axis=1))
    t = np.broadcast_to(np.linspace(0.0, 1.0, L, dtype=np.float32), (b, L))
    x = np.stack([t, s], axis=-1)  # (b, L, 2)
    return jnp.asarray(x), jnp.asarray(y)


def test_signature_fn_pallas_equals_ref():
    rng = np.random.default_rng(0)
    path = jnp.asarray(rng.normal(size=(8, 16, 3)).astype(np.float32).cumsum(axis=1) * 0.2)
    a = model.signature_fn(path, 3, use_pallas=True, tile=4)
    b = model.signature_fn(path, 3, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=1e-5)


def test_logsignature_fn_shapes_and_values():
    rng = np.random.default_rng(1)
    path = jnp.asarray(rng.normal(size=(4, 12, 3)).astype(np.float32).cumsum(axis=1) * 0.2)
    z = model.logsignature_fn(path, 3, use_pallas=False)
    assert z.shape == (4, ref.witt_dimension(3, 3))
    expect = ref.logsignature_words_ref(path, 3)
    np.testing.assert_allclose(np.asarray(z), np.asarray(expect), rtol=1e-4, atol=1e-5)
    # Level-1 coefficients are the total increment.
    incr = path[:, -1] - path[:, 0]
    np.testing.assert_allclose(np.asarray(z[:, :3]), np.asarray(incr), rtol=1e-4, atol=1e-5)


def test_deep_model_shapes():
    params = model.init_params(2, 16, 4, 3)
    rng = np.random.default_rng(2)
    x, y = gbm_batch(rng, 8, 32)
    logits = model.deep_sig_logits(params, x, 3, use_pallas=False, tile=8)
    assert logits.shape == (8,)
    loss = model.bce_loss(params, x, y, 3, False, 8)
    assert np.isfinite(float(loss))


def test_train_step_decreases_loss():
    params = model.init_params(2, 16, 4, 3, seed=0)
    rng = np.random.default_rng(3)
    x, y = gbm_batch(rng, 32, 32)
    step = jax.jit(
        lambda pr, xx, yy, lr: model.train_step(
            model.DeepSigParams(*pr), xx, yy, lr, depth=3, use_pallas=False
        )
    )
    first_loss = None
    pr = tuple(params)
    for i in range(60):
        out = step(pr, x, y, jnp.float32(0.05))
        pr, loss = out[:-1], float(out[-1])
        if first_loss is None:
            first_loss = loss
    assert loss < first_loss, (first_loss, loss)


def test_train_step_artifact_calling_convention():
    # The lowered train step consumes (6 params, x, y, lr) positionally and
    # returns (6 params, loss): the convention rust/src/deepsig relies on.
    params = model.init_params(2, 16, 4, 3)
    rng = np.random.default_rng(4)
    x, y = gbm_batch(rng, 32, 64)
    out = model.train_step(params, x, y, jnp.float32(0.1), depth=3, use_pallas=False)
    assert len(out) == 7
    for p_new, p_old in zip(out[:-1], params):
        assert p_new.shape == p_old.shape


def test_gbm_classes_are_separable_statistically():
    # Sanity of the synthetic task: high-vol paths have larger quadratic
    # variation; the dataset must be learnable.
    rng = np.random.default_rng(5)
    x, y = gbm_batch(rng, 256, 64)
    qv = np.sum(np.diff(np.asarray(x[..., 1]), axis=1) ** 2, axis=1)
    hi = qv[np.asarray(y) == 1.0].mean()
    lo = qv[np.asarray(y) == 0.0].mean()
    assert hi > 3 * lo
