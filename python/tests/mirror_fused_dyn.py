#!/usr/bin/env python3
"""Pure-NumPy mirror of the runtime-`d` fused Horner kernels — the pre-CI gate.

Transliterates, operation for operation, the Rust kernels in
`rust/src/ta/fused.rs` and `rust/src/ta/batch.rs`:

  * ``fused_mexp_generic``   — runtime-`d` forward Horner (``A <- A (x) exp(z)``)
  * ``fused_mexp_vjp_dyn``   — runtime-`d` reverse through the Horner scheme
  * ``fused_mexp_batch``     — lane-interleaved forward twin
  * ``fused_mexp_vjp_batch`` — lane-interleaved backward twin
  * ``mul_batch_into`` / ``inverse_batch_into`` / ``exp_batch_in_place`` —
    the lane-interleaved Chen-combination kernels behind batched
    window-slide advancement, against their scalar twins ``mul_into`` /
    ``inverse_into`` / ``exp_in_place``

and validates, with no Rust toolchain required:

  1. the runtime-`d` forward against the unfused exp + tensor-product
     composition (f64, rel err ~1e-13);
  2. the runtime-`d` VJP against full central-difference Jacobians in f64 at
     the issue's dimension sweep d in {3, 8, 9, 12, 20} — both inside and
     beyond the Rust mono window (d <= 8), where the dyn body is the only
     dispatch target;
  3. f32 kernel consistency against the f64 kernel on identical inputs;
  4. per-lane **bitwise** parity of the lane-interleaved kernels against the
     scalar runtime-`d` kernels, in BOTH precisions, at lane counts
     {1, 3, 5} that leave ragged tails against the planner's narrowest
     16-lane tier;
  5. the typed data plane end to end: a full path -> signature serve in
     native f64 (increments through the fused kernel, exactly the serving
     pipeline's op sequence) against the unfused float64 oracle at
     rel ~1e-12 — a bar a serve that silently round-trips through f32
     cannot clear (demonstrated: the widened-f32 serve is rejected) —
     plus bitwise session-feed == stateless and per-lane bitwise batch
     serving, all at f64.

Reductions are accumulated in exactly the Rust op order (sequential, never
``np.sum``'s pairwise tree), so bitwise comparisons are meaningful: a
transcription drift between the scalar and batched Rust loops would show up
here as a bit mismatch in f32.

Run:  python3 python/tests/mirror_fused_dyn.py
Exits nonzero on any failure. Uses only numpy — deliberately importable with
neither jax nor a Rust toolchain on the machine.
"""

import math
import sys

import numpy as np


class Spec:
    """Mirror of ta::SigSpec — flat layout, level k at off(k), d^k entries."""

    def __init__(self, d, depth):
        self.d = d
        self.depth = depth
        offs = [0]
        for k in range(1, depth + 1):
            offs.append(offs[-1] + d**k)
        self._off = offs
        self.sig_len = offs[-1]

    def off(self, k):
        return self._off[k - 1]

    def level_len(self, k):
        return self._off[k] - self._off[k - 1]


def recip(k, dt):
    # Elem::recip_usize: ONE / from_usize(k), rounded once in E.
    return dt(1.0) / dt(k)


def stage_zdiv(spec, z, dt):
    """zdiv row m-1 holds z * (1/m) — one rounded multiply per entry."""
    out = np.empty((spec.depth,) + z.shape, dtype=dt)
    for m in range(1, spec.depth + 1):
        out[m - 1] = z * recip(m, dt)
    return out


# ---------------------------------------------------------------- scalar ---


def fused_mexp_dyn(spec, a, z):
    """In-place A <- A (x) exp(z): mirror of fused_mexp_generic."""
    d, n, dt = spec.d, spec.depth, a.dtype.type
    zdiv = stage_zdiv(spec, z, dt)
    for k in range(n, 1, -1):
        # B_1 = z/k + A_1.
        cur = zdiv[k - 1] + a[:d]
        cur_len = d
        for i in range(2, k):
            # B_i = B_{i-1} (o) z/(k-i+1) + A_i: mul then add, elementwise.
            m = k - i + 1
            oi, li = spec.off(i), spec.level_len(i)
            ai = a[oi : oi + li].reshape(cur_len, d)
            cur = (cur[:, None] * zdiv[m - 1][None, :] + ai).ravel()
            cur_len *= d
        # Final step in place: A_k += B_{k-1} (o) z.
        ok = spec.off(k)
        a[ok : ok + cur_len * d] += (cur[:, None] * z[None, :]).ravel()
    a[:d] += z


def fused_mexp_vjp_dyn(spec, a, z, g):
    """Mirror of fused_mexp_vjp_dyn; returns (ga, gz) accumulated from zero.

    Every reduction runs in the Rust loop order: per-row accumulators add
    q-major (vectorised over rows), the gz accumulators add p-major
    (vectorised over q) — sequential adds, never pairwise trees.
    """
    d, n, dt = spec.d, spec.depth, a.dtype.type
    ga = np.zeros(spec.sig_len, dtype=dt)
    gz = np.zeros(d, dtype=dt)
    zdiv = stage_zdiv(spec, z, dt)
    # Level 1: C_1 = A_1 + z.
    ga[:d] += g[:d]
    gz += g[:d]
    for k in range(n, 1, -1):
        # Recompute the forward chain for level k, keeping every B_i.
        B = {1: zdiv[k - 1] + a[:d]}
        cur = B[1]
        cur_len = d
        for i in range(2, k):
            m = k - i + 1
            oi, li = spec.off(i), spec.level_len(i)
            ai = a[oi : oi + li].reshape(cur_len, d)
            cur = (cur[:, None] * zdiv[m - 1][None, :] + ai).ravel()
            cur_len *= d
            B[i] = cur
        # Unwind. Final step: C_k = B_{k-1} (o) z + A_k.
        ok, lk = spec.off(k), spec.level_len(k)
        ga[ok : ok + lk] += g[ok : ok + lk]
        gk = g[ok : ok + lk].reshape(cur_len, d)
        bk1 = B[k - 1]
        gb = np.zeros(cur_len, dtype=dt)
        for q in range(d):  # acc += row[q] * z[q], q-major per row
            gb += gk[:, q] * z[q]
        for p in range(cur_len):  # gz[q] += B_{k-1}[p] * gk[p, q], p-major
            gz += bk1[p] * gk[p]
        # Middle steps: B_i = B_{i-1} (o) z/m + A_i, i = k-1 .. 2.
        len_i = cur_len
        for i in range(k - 1, 1, -1):
            m = k - i + 1
            inv_m = recip(m, dt)
            zm = zdiv[m - 1]
            oi = spec.off(i)
            prev_len = len_i // d
            b_prev = B[i - 1]
            ga[oi : oi + len_i] += gb
            rows = gb.reshape(prev_len, d)
            gb_prev = np.zeros(prev_len, dtype=dt)
            for q in range(d):
                gb_prev += rows[:, q] * zm[q]
            gz_acc = np.zeros(d, dtype=dt)
            for p in range(prev_len):
                gz_acc += b_prev[p] * rows[p]
            gz += inv_m * gz_acc
            gb = gb_prev
            len_i = prev_len
        # Innermost: B_1 = z/k + A_1.
        inv_k = recip(k, dt)
        ga[:d] += gb
        gz += inv_k * gb
    return ga, gz


# ----------------------------------------------------------------- batch ---
# Lane-interleaved layout buf[i*L + l] is modelled as arrays of shape
# (item_len, L): the lane axis is last/contiguous, exactly as in Rust.


def fused_mexp_batch(spec, a, z):
    """In-place lane-fused forward: mirror of ta::batch::fused_mexp_batch."""
    d, n, dt = spec.d, spec.depth, a.dtype.type
    L = a.shape[1]
    zdiv = stage_zdiv(spec, z, dt)  # (depth, d, L)
    for k in range(n, 1, -1):
        cur = zdiv[k - 1] + a[:d]  # (d, L)
        cur_len = d
        for i in range(2, k):
            m = k - i + 1
            oi, li = spec.off(i), spec.level_len(i)
            ai = a[oi : oi + li].reshape(cur_len, d, L)
            cur = (cur[:, None, :] * zdiv[m - 1][None, :, :] + ai).reshape(-1, L)
            cur_len *= d
        ok = spec.off(k)
        a[ok : ok + cur_len * d] += (
            cur.reshape(cur_len, 1, L) * z[None, :, :]
        ).reshape(-1, L)
    a[:d] += z


def fused_mexp_vjp_batch(spec, a, z, g):
    """Mirror of ta::batch::fused_mexp_vjp_batch; returns (ga, gz).

    Same accumulation orders as the Rust batch kernel: per-row accumulators
    start from fill(ZERO) and add q-major; gz adds p-major; the per-step
    gz accumulator (ws.gza) is zeroed and drained per middle step.
    """
    d, n, dt = spec.d, spec.depth, a.dtype.type
    L = a.shape[1]
    ga = np.zeros((spec.sig_len, L), dtype=dt)
    gz = np.zeros((d, L), dtype=dt)
    zdiv = stage_zdiv(spec, z, dt)
    ga[:d] += g[:d]
    gz += g[:d]
    for k in range(n, 1, -1):
        B = {1: zdiv[k - 1] + a[:d]}
        cur = B[1]
        cur_len = d
        for i in range(2, k):
            m = k - i + 1
            oi, li = spec.off(i), spec.level_len(i)
            ai = a[oi : oi + li].reshape(cur_len, d, L)
            cur = (cur.reshape(cur_len, 1, L) * zdiv[m - 1][None, :, :] + ai).reshape(
                -1, L
            )
            cur_len *= d
            B[i] = cur
        ok, lk = spec.off(k), spec.level_len(k)
        ga[ok : ok + lk] += g[ok : ok + lk]
        gk = g[ok : ok + lk].reshape(cur_len, d, L)
        bk1 = B[k - 1].reshape(cur_len, L)
        gb = np.zeros((cur_len, L), dtype=dt)
        for q in range(d):
            gb += gk[:, q, :] * z[q]
        for p in range(cur_len):
            gz += bk1[p][None, :] * gk[p]
        len_i = cur_len
        for i in range(k - 1, 1, -1):
            m = k - i + 1
            inv_m = recip(m, dt)
            zm = zdiv[m - 1]
            oi = spec.off(i)
            prev_len = len_i // d
            b_prev = B[i - 1].reshape(prev_len, L)
            ga[oi : oi + len_i] += gb
            rows = gb.reshape(prev_len, d, L)
            gb_prev = np.zeros((prev_len, L), dtype=dt)
            for q in range(d):
                gb_prev += rows[:, q, :] * zm[q]
            gz_acc = np.zeros((d, L), dtype=dt)
            for p in range(prev_len):
                gz_acc += b_prev[p][None, :] * rows[p]
            gz += inv_m * gz_acc
            gb = gb_prev.reshape(-1, L)
            len_i = prev_len
        inv_k = recip(k, dt)
        ga[:d] += gb.reshape(d, L)
        gz += inv_k * gb.reshape(d, L)
    return ga, gz


# ----------------------------------------------------- Chen combination ---
# Mirrors of the lane-interleaved window-slide kernels (`mul_batch_into`,
# `inverse_batch_into`, `exp_batch_in_place` in rust/src/ta/batch.rs) and
# their scalar twins (`mul_into`, `inverse_into`, `exp_in_place`). The
# batched buffers buf[e*L + l] are modelled as arrays of shape
# (item_len, L), lane axis last, exactly as above. Every accumulation runs
# one elementwise add per `i` term, in `i` order, matching the Rust loops.


def mul_into_dyn(spec, a, b):
    """Scalar full (x) with implicit units: mirror of mul::mul_into."""
    dt = a.dtype.type
    out = np.empty(spec.sig_len, dtype=dt)
    for k in range(1, spec.depth + 1):
        ok, lk = spec.off(k), spec.level_len(k)
        out[ok : ok + lk] = a[ok : ok + lk] + b[ok : ok + lk]
        for i in range(1, k):
            ai = a[spec.off(i) : spec.off(i) + spec.level_len(i)]
            bj = b[spec.off(k - i) : spec.off(k - i) + spec.level_len(k - i)]
            out[ok : ok + lk] += (ai[:, None] * bj[None, :]).ravel()
    return out


def mul_nounit_dyn(spec, a, b):
    """Scalar no-unit (x): mirror of mul::mul_nounit_into (out_1 = 0)."""
    dt = a.dtype.type
    out = np.zeros(spec.sig_len, dtype=dt)
    for k in range(1, spec.depth + 1):
        ok, lk = spec.off(k), spec.level_len(k)
        for i in range(1, k):
            ai = a[spec.off(i) : spec.off(i) + spec.level_len(i)]
            bj = b[spec.off(k - i) : spec.off(k - i) + spec.level_len(k - i)]
            out[ok : ok + lk] += (ai[:, None] * bj[None, :]).ravel()
    return out


def inverse_dyn(spec, x):
    """Scalar group inverse: mirror of inverse::inverse_into.

    The Horner-style fixpoint t_1 = -x; t_i = -(x + x (x)' t_{i-1}).
    """
    out = -x
    for _ in range(2, spec.depth + 1):
        out = -(x + mul_nounit_dyn(spec, x, out))
    return out


def exp_in_place_dyn(spec, out):
    """Scalar in-place exp from a staged level 1: mirror of exp_in_place."""
    d, dt = spec.d, out.dtype.type
    z = out[:d].copy()
    for k in range(2, spec.depth + 1):
        inv_k = recip(k, dt)
        ok = spec.off(k)
        prev = out[spec.off(k - 1) : ok]
        out[ok : ok + spec.level_len(k)] = (prev[:, None] * z[None, :] * inv_k).ravel()


def mul_batch(spec, a, b):
    """Lane-fused full (x): mirror of ta::batch::mul_batch_into."""
    dt = a.dtype.type
    L = a.shape[1]
    out = np.empty((spec.sig_len, L), dtype=dt)
    for k in range(1, spec.depth + 1):
        ok, lk = spec.off(k), spec.level_len(k)
        out[ok : ok + lk] = a[ok : ok + lk] + b[ok : ok + lk]
        for i in range(1, k):
            ai = a[spec.off(i) : spec.off(i) + spec.level_len(i)]
            bj = b[spec.off(k - i) : spec.off(k - i) + spec.level_len(k - i)]
            out[ok : ok + lk] += (ai[:, None, :] * bj[None, :, :]).reshape(-1, L)
    return out


def mul_nounit_batch(spec, a, b):
    """Lane-fused no-unit (x): mirror of mul_nounit_batch_into."""
    dt = a.dtype.type
    L = a.shape[1]
    out = np.zeros((spec.sig_len, L), dtype=dt)
    for k in range(1, spec.depth + 1):
        ok, lk = spec.off(k), spec.level_len(k)
        for i in range(1, k):
            ai = a[spec.off(i) : spec.off(i) + spec.level_len(i)]
            bj = b[spec.off(k - i) : spec.off(k - i) + spec.level_len(k - i)]
            out[ok : ok + lk] += (ai[:, None, :] * bj[None, :, :]).reshape(-1, L)
    return out


def inverse_batch(spec, x):
    """Lane-fused group inverse: mirror of inverse_batch_into."""
    out = -x
    for _ in range(2, spec.depth + 1):
        out = -(x + mul_nounit_batch(spec, x, out))
    return out


def exp_batch_in_place(spec, out):
    """Lane-fused in-place exp: mirror of exp_batch_in_place."""
    d, dt = spec.d, out.dtype.type
    L = out.shape[1]
    z = out[:d].copy()
    for k in range(2, spec.depth + 1):
        inv_k = recip(k, dt)
        ok = spec.off(k)
        prev = out[spec.off(k - 1) : ok]
        out[ok : ok + spec.level_len(k)] = (
            prev[:, None, :] * z[None, :, :] * inv_k
        ).reshape(-1, L)


# --------------------------------------------------------------- serving ---


def serve_signature_dyn(spec, pts):
    """Mirror of the stateless serving pipeline at the rows' native width.

    The coordinator turns a path into increments and drives the fused
    Horner kernel once per increment, starting from the zero tensor (the
    first step then lands exactly on exp(z_1)). The element type of ``pts``
    is the element type of every intermediate — nothing widens or narrows.
    """
    dt = pts.dtype.type
    sig = np.zeros(spec.sig_len, dtype=dt)
    for t in range(1, pts.shape[0]):
        fused_mexp_dyn(spec, sig, (pts[t] - pts[t - 1]).astype(dt))
    return sig


def serve_signature_chunked(spec, pts, chunks):
    """Session mirror: OpenStream on the first chunk, Feed for the rest.

    Each feed resumes from the stored running signature; the op sequence
    must be identical to the stateless serve, so the result is bitwise
    equal — the invariant the Rust session arm pins.
    """
    dt = pts.dtype.type
    sig = np.zeros(spec.sig_len, dtype=dt)
    prev = pts[0]
    start = 1
    for n in chunks:
        for t in range(start, start + n):
            fused_mexp_dyn(spec, sig, (pts[t] - prev).astype(dt))
            prev = pts[t]
        start += n
    return sig


def serve_signature_batch(spec, paths):
    """Lane-interleaved batch serve: mirror of the planner's lane driver.

    ``paths`` has shape (L, points, d); lanes advance in lockstep through
    the shared increment loop, exactly as the Rust lane kernel packs them.
    """
    L, points, d = paths.shape
    dt = paths.dtype.type
    sig = np.zeros((spec.sig_len, L), dtype=dt)
    for t in range(1, points):
        z_il = np.ascontiguousarray((paths[:, t] - paths[:, t - 1]).T.astype(dt))
        fused_mexp_batch(spec, sig, z_il)
    return sig


# ------------------------------------------------------------- reference ---


def exp_ref(spec, z):
    """exp(z) in the truncated algebra: level k = z^(o k) / k! (f64)."""
    e = np.zeros(spec.sig_len, dtype=np.float64)
    cur = z.astype(np.float64).copy()
    e[: spec.d] = cur
    for k in range(2, spec.depth + 1):
        cur = (cur[:, None] * z[None, :]).ravel()
        e[spec.off(k) : spec.off(k) + spec.level_len(k)] = cur / math.factorial(k)
    return e


def mul_ref(spec, a, b):
    """(a (x) b) with the implicit unit scalar: out_k = a_k + b_k + sum a_i (o) b_{k-i}."""
    out = np.zeros(spec.sig_len, dtype=np.float64)
    for k in range(1, spec.depth + 1):
        ok, lk = spec.off(k), spec.level_len(k)
        out[ok : ok + lk] = a[ok : ok + lk] + b[ok : ok + lk]
        for i in range(1, k):
            ai = a[spec.off(i) : spec.off(i) + spec.level_len(i)]
            bj = b[spec.off(k - i) : spec.off(k - i) + spec.level_len(k - i)]
            out[ok : ok + lk] += (ai[:, None] * bj[None, :]).ravel()
    return out


def signature_oracle(spec, pts):
    """Unfused float64 oracle for a whole path: Chen-compose exp(z_t)."""
    pts64 = pts.astype(np.float64)
    sig = exp_ref(spec, pts64[1] - pts64[0])
    for t in range(2, pts64.shape[0]):
        sig = mul_ref(spec, sig, exp_ref(spec, pts64[t] - pts64[t - 1]))
    return sig


# ---------------------------------------------------------------- checks ---

FAILURES = []


def check(name, ok, detail=""):
    status = "ok  " if ok else "FAIL"
    print(f"  [{status}] {name}" + (f"  ({detail})" if detail else ""))
    if not ok:
        FAILURES.append(name)


def rel_err(x, y):
    scale = max(np.abs(y).max(), 1e-30)
    return np.abs(x - y).max() / scale


def check_forward_vs_reference(d, depth, seed):
    spec = Spec(d, depth)
    rng = np.random.default_rng(seed)
    a0 = rng.standard_normal(spec.sig_len) * 0.4
    z = rng.standard_normal(d) * 0.4
    out = a0.copy()
    fused_mexp_dyn(spec, out, z)
    ref = mul_ref(spec, a0, exp_ref(spec, z))
    err = rel_err(out, ref)
    check(f"forward dyn == unfused reference  d={d} depth={depth}", err < 1e-12, f"rel {err:.2e}")


def check_vjp_vs_fd(d, depth, seed, h=1e-6):
    """Full central-difference Jacobian check of the dyn VJP, f64."""
    spec = Spec(d, depth)
    rng = np.random.default_rng(seed)
    a = rng.standard_normal(spec.sig_len) * 0.4
    z = rng.standard_normal(d) * 0.4
    g = rng.standard_normal(spec.sig_len)

    def loss(av, zv):
        out = av.copy()
        fused_mexp_dyn(spec, out, zv)
        return float(g @ out)

    ga, gz = fused_mexp_vjp_dyn(spec, a, z, g)
    fd_ga = np.empty_like(a)
    for j in range(spec.sig_len):
        ap, am = a.copy(), a.copy()
        ap[j] += h
        am[j] -= h
        fd_ga[j] = (loss(ap, z) - loss(am, z)) / (2 * h)
    fd_gz = np.empty_like(z)
    for j in range(d):
        zp, zm = z.copy(), z.copy()
        zp[j] += h
        zm[j] -= h
        fd_gz[j] = (loss(a, zp) - loss(a, zm)) / (2 * h)
    ea, ez = rel_err(ga, fd_ga), rel_err(gz, fd_gz)
    check(
        f"vjp dyn == FD Jacobian (f64)      d={d} depth={depth}",
        ea < 1e-6 and ez < 1e-6,
        f"rel ga {ea:.2e} gz {ez:.2e}",
    )


def check_f32_tracks_f64(d, depth, seed):
    spec = Spec(d, depth)
    rng = np.random.default_rng(seed)
    a32 = (rng.standard_normal(spec.sig_len) * 0.3).astype(np.float32)
    z32 = (rng.standard_normal(d) * 0.3).astype(np.float32)
    g32 = rng.standard_normal(spec.sig_len).astype(np.float32)
    out32 = a32.copy()
    fused_mexp_dyn(spec, out32, z32)
    out64 = a32.astype(np.float64)
    fused_mexp_dyn(spec, out64, z32.astype(np.float64))
    ef = rel_err(out32.astype(np.float64), out64)
    ga32, gz32 = fused_mexp_vjp_dyn(spec, a32, z32, g32)
    ga64, gz64 = fused_mexp_vjp_dyn(
        spec, a32.astype(np.float64), z32.astype(np.float64), g32.astype(np.float64)
    )
    eg = max(rel_err(ga32.astype(np.float64), ga64), rel_err(gz32.astype(np.float64), gz64))
    check(
        f"f32 kernels track f64             d={d} depth={depth}",
        ef < 1e-4 and eg < 1e-4,
        f"rel fwd {ef:.2e} vjp {eg:.2e}",
    )


def check_lane_parity(d, depth, lanes, dt, seed):
    """Bitwise: lane-interleaved kernels == scalar dyn kernels per lane."""
    spec = Spec(d, depth)
    rng = np.random.default_rng(seed)
    a_rows = (rng.standard_normal((lanes, spec.sig_len)) * 0.4).astype(dt)
    z_rows = (rng.standard_normal((lanes, d)) * 0.4).astype(dt)
    g_rows = rng.standard_normal((lanes, spec.sig_len)).astype(dt)
    # pack: buf[i*L + l] = row_l[i]  ->  shape (item_len, L)
    a_il = np.ascontiguousarray(a_rows.T)
    z_il = np.ascontiguousarray(z_rows.T)
    g_il = np.ascontiguousarray(g_rows.T)
    fwd = a_il.copy()
    fused_mexp_batch(spec, fwd, z_il)
    ga_b, gz_b = fused_mexp_vjp_batch(spec, a_il, z_il, g_il)
    ok_f = ok_b = True
    for l in range(lanes):
        ref = a_rows[l].copy()
        fused_mexp_dyn(spec, ref, z_rows[l])
        ok_f &= np.array_equal(fwd[:, l], ref)
        ga_s, gz_s = fused_mexp_vjp_dyn(spec, a_rows[l], z_rows[l], g_rows[l])
        ok_b &= np.array_equal(ga_b[:, l], ga_s) and np.array_equal(gz_b[:, l], gz_s)
    prec = "f32" if dt == np.float32 else "f64"
    check(
        f"lane kernels bitwise == scalar    d={d} depth={depth} L={lanes} {prec}",
        ok_f and ok_b,
        "fwd+vjp, per-lane exact bits",
    )


def check_chen_semantics(d, depth, seed):
    """f64 semantic gates for the Chen mirrors before the bitwise gates.

    mul_into_dyn must agree with the independent mul_ref oracle; the
    inverse must actually invert (x (x) x^{-1} has every non-unit level
    ~0); the staged in-place exp must match the factorial reference.
    """
    spec = Spec(d, depth)
    rng = np.random.default_rng(seed)
    a = rng.standard_normal(spec.sig_len) * 0.4
    b = rng.standard_normal(spec.sig_len) * 0.4
    z = rng.standard_normal(d) * 0.4
    em = rel_err(mul_into_dyn(spec, a, b), mul_ref(spec, a, b))
    resid = mul_ref(spec, a, inverse_dyn(spec, a))
    ei = np.abs(resid).max()
    staged = np.zeros(spec.sig_len, dtype=np.float64)
    staged[:d] = z
    exp_in_place_dyn(spec, staged)
    ee = rel_err(staged, exp_ref(spec, z))
    check(
        f"chen mirrors == oracles (f64)     d={d} depth={depth}",
        em < 1e-13 and ei < 1e-12 and ee < 1e-12,
        f"mul {em:.2e} inv-resid {ei:.2e} exp {ee:.2e}",
    )


def check_chen_lane_parity(d, depth, lanes, dt, seed):
    """Bitwise: the window-slide Chen kernels == their scalar twins.

    Packs random (A, B, z) rows lane-interleaved and asserts
    mul_batch / inverse_batch / exp_batch_in_place reproduce
    mul_into_dyn / inverse_dyn / exp_in_place_dyn per lane, exact bits —
    the invariant `RollingWindow::advance_batch` rests on.
    """
    spec = Spec(d, depth)
    rng = np.random.default_rng(seed)
    a_rows = (rng.standard_normal((lanes, spec.sig_len)) * 0.4).astype(dt)
    b_rows = (rng.standard_normal((lanes, spec.sig_len)) * 0.4).astype(dt)
    z_rows = (rng.standard_normal((lanes, d)) * 0.4).astype(dt)
    a_il = np.ascontiguousarray(a_rows.T)
    b_il = np.ascontiguousarray(b_rows.T)
    mul_b = mul_batch(spec, a_il, b_il)
    inv_b = inverse_batch(spec, a_il)
    exp_b = np.zeros((spec.sig_len, lanes), dtype=dt)
    exp_b[:d] = np.ascontiguousarray(z_rows.T)
    exp_batch_in_place(spec, exp_b)
    ok_m = ok_i = ok_e = True
    for l in range(lanes):
        ok_m &= np.array_equal(mul_b[:, l], mul_into_dyn(spec, a_rows[l], b_rows[l]))
        ok_i &= np.array_equal(inv_b[:, l], inverse_dyn(spec, a_rows[l]))
        exp_s = np.zeros(spec.sig_len, dtype=dt)
        exp_s[:d] = z_rows[l]
        exp_in_place_dyn(spec, exp_s)
        ok_e &= np.array_equal(exp_b[:, l], exp_s)
    prec = "f32" if dt == np.float32 else "f64"
    check(
        f"chen kernels bitwise == scalar    d={d} depth={depth} L={lanes} {prec}",
        ok_m and ok_i and ok_e,
        "mul+inverse+exp, per-lane exact bits",
    )


def check_f64_serving(d, depth, seed, points=7, lanes=3):
    """End-to-end typed serve at f64: oracle gate + session + lane parity.

    The oracle bar (rel < 1e-12) is the native-width gate: it also asserts
    the f32-then-widen serve FAILS it, so the threshold genuinely
    discriminates a pipeline that kept rows at f64 from one that silently
    bounced through f32.
    """
    spec = Spec(d, depth)
    rng = np.random.default_rng(seed)
    paths64 = rng.standard_normal((lanes, points, d)) * 0.3

    # Stateless f64 serve vs the unfused float64 oracle.
    pts = paths64[0]
    served = serve_signature_dyn(spec, pts)
    oracle = signature_oracle(spec, pts)
    e64 = rel_err(served, oracle)
    # The impostor: same rows narrowed to f32 for the serve, answer widened
    # back — what a Vec<f32> wire format would have produced.
    e32 = rel_err(serve_signature_dyn(spec, pts.astype(np.float32)).astype(np.float64), oracle)
    check(
        f"f64 serve == float64 oracle       d={d} depth={depth}",
        e64 < 1e-12,
        f"rel {e64:.2e}",
    )
    check(
        f"oracle bar rejects f32 round-trip d={d} depth={depth}",
        e32 > 1e-8 > e64,
        f"widened-f32 rel {e32:.2e}",
    )

    # Session arm: OpenStream(2 points) + two Feeds == stateless, bitwise.
    chunked = serve_signature_chunked(spec, pts, [1, 2, points - 5, 1])
    check(
        f"f64 session feeds bitwise == stateless  d={d} depth={depth}",
        np.array_equal(chunked, served),
        "exact bits",
    )

    # Batch arm: lane-interleaved f64 serve, per-lane bitwise vs scalar.
    lane_sigs = serve_signature_batch(spec, paths64)
    ok = all(
        np.array_equal(lane_sigs[:, l], serve_signature_dyn(spec, paths64[l]))
        for l in range(lanes)
    )
    check(
        f"f64 lane serve bitwise == scalar  d={d} depth={depth} L={lanes}",
        ok,
        "per-lane exact bits",
    )


def main():
    # The issue's dimension sweep: inside the mono window (3, 8), just past
    # it (9), and the wide serving shapes (12, 20). Depths chosen as in the
    # Rust sweep tests, keeping d=20 inside the script's budget.
    sweep = [(3, 4), (8, 3), (9, 3), (12, 3), (20, 2)]

    print("forward: runtime-d Horner vs unfused exp + (x) composition (f64)")
    for i, (d, depth) in enumerate(sweep):
        check_forward_vs_reference(d, depth, 1000 + i)

    print("backward: runtime-d VJP vs full central-difference Jacobians (f64)")
    for i, (d, depth) in enumerate(sweep):
        check_vjp_vs_fd(d, depth, 2000 + i)

    print("precision axis: f32 kernels vs f64 kernels on identical inputs")
    for i, (d, depth) in enumerate(sweep):
        check_f32_tracks_f64(d, depth, 3000 + i)

    print("lane engine: bitwise per-lane parity incl. ragged tails (L in {1,3,5})")
    for dt in (np.float32, np.float64):
        for i, (d, depth) in enumerate(sweep):
            for lanes in (1, 3, 5):
                check_lane_parity(d, depth, lanes, dt, 4000 + 31 * i + lanes)

    print("chen combination: window-slide kernels, oracle + bitwise lane parity")
    for i, (d, depth) in enumerate(sweep):
        check_chen_semantics(d, depth, 6000 + i)
    for dt in (np.float32, np.float64):
        for i, (d, depth) in enumerate(sweep):
            for lanes in (1, 3, 5):
                check_chen_lane_parity(d, depth, lanes, dt, 7000 + 31 * i + lanes)

    print("typed serving: end-to-end f64 path -> signature vs float64 oracle")
    for i, (d, depth) in enumerate(sweep):
        check_f64_serving(d, depth, 5000 + i)

    if FAILURES:
        print(f"\n{len(FAILURES)} mirror check(s) FAILED:")
        for f in FAILURES:
            print(f"  - {f}")
        return 1
    print("\nall mirror checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
