"""L1 correctness: the Pallas fused-step kernel vs the pure-jnp oracle.

This is the CORE correctness signal of the compile path: hypothesis sweeps
shapes (batch/stream/channels/depth), tiles and dtypes, asserting
allclose against ref.py everywhere.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fused_step import fused_step, signature_pallas, vmem_estimate_bytes


def rand_state(rng, b, d, depth):
    return jnp.asarray(rng.normal(size=(b, ref.sig_len(d, depth))).astype(np.float32))


def rand_z(rng, b, d, scale=0.5):
    return jnp.asarray((rng.normal(size=(b, d)) * scale).astype(np.float32))


def rand_path(rng, b, L, d, scale=0.3):
    steps = rng.normal(size=(b, L, d)).astype(np.float32) * scale
    return jnp.asarray(np.cumsum(steps, axis=1))


@settings(max_examples=25, deadline=None)
@given(
    d=st.integers(1, 5),
    depth=st.integers(1, 5),
    tile_pow=st.integers(0, 3),
    tiles=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_step_matches_ref(d, depth, tile_pow, tiles, seed):
    tile = 2**tile_pow
    b = tile * tiles
    rng = np.random.default_rng(seed)
    state = rand_state(rng, b, d, depth)
    z = rand_z(rng, b, d)
    out = fused_step(state, z, d, depth, tile)
    expect = ref.fused_step_ref(state, z, d, depth)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    d=st.integers(1, 4),
    depth=st.integers(1, 4),
    L=st.integers(2, 24),
    b=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_signature_pallas_matches_ref(d, depth, L, b, seed):
    rng = np.random.default_rng(seed)
    path = rand_path(rng, b, L, d)
    tile = 1 if b == 1 else min(b, 4)
    got = signature_pallas(path, depth, tile=tile)
    expect = ref.signature_ref(path, depth)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), rtol=2e-4, atol=1e-5)


def test_fused_step_rejects_bad_tile():
    rng = np.random.default_rng(0)
    state = rand_state(rng, 6, 2, 3)
    z = rand_z(rng, 6, 2)
    with pytest.raises(AssertionError):
        fused_step(state, z, 2, 3, 4)  # 6 % 4 != 0


def test_fused_step_identity_state_is_exp():
    # From the zero (identity) state the fused step produces exp(z).
    rng = np.random.default_rng(3)
    d, depth, b = 3, 4, 8
    z = rand_z(rng, b, d)
    state = jnp.zeros((b, ref.sig_len(d, depth)), jnp.float32)
    out = fused_step(state, z, d, depth, 4)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.tensor_exp(z, depth)), rtol=1e-5, atol=1e-6
    )


@settings(max_examples=10, deadline=None)
@given(d=st.integers(1, 4), depth=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
def test_chen_identity_ref(d, depth, seed):
    # ref.sig_mul obeys Chen: Sig(whole) = Sig(left) ⊠ Sig(right).
    rng = np.random.default_rng(seed)
    path = rand_path(rng, 2, 11, d)
    full = ref.signature_ref(path, depth)
    left = ref.signature_ref(path[:, :6], depth)
    right = ref.signature_ref(path[:, 5:], depth)
    np.testing.assert_allclose(
        np.asarray(ref.sig_mul(left, right, d, depth)),
        np.asarray(full),
        rtol=2e-4,
        atol=1e-5,
    )


def test_log_of_exp_is_increment():
    rng = np.random.default_rng(5)
    d, depth = 3, 5
    z = rand_z(rng, 4, d)
    e = ref.tensor_exp(z, depth)
    logt = ref.tensor_log(e, d, depth)
    expect = np.zeros(np.asarray(logt).shape, np.float32)
    expect[:, :d] = np.asarray(z)
    np.testing.assert_allclose(np.asarray(logt), expect, rtol=1e-4, atol=1e-5)


def test_lyndon_indices_match_witt():
    for d in range(1, 6):
        for depth in range(1, 6):
            assert ref.witt_dimension(d, depth) == ref.witt_check(d, depth)


def test_opcount_fused_below_conventional():
    # App. A.1.3, mirrored in rust/src/ta/opcount.rs.
    for d in range(1, 8):
        for n in range(1, 10):
            assert ref.count_fused_muls(d, n) <= ref.count_conventional_muls(d, n)


def test_gradients_flow_through_pallas_kernel():
    # jax.grad through the interpret-mode kernel equals grad through ref.
    rng = np.random.default_rng(7)
    d, depth, b, L = 2, 3, 4, 6
    path = rand_path(rng, b, L, d)

    g1 = jax.grad(lambda p: jnp.sum(signature_pallas(p, depth, tile=2)))(path)
    g2 = jax.grad(lambda p: jnp.sum(ref.signature_ref(p, depth)))(path)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=2e-4, atol=1e-5)


def test_vmem_estimate_sane():
    # d=4,N=4 tile=8 state fits comfortably in a 16MB VMEM budget.
    assert vmem_estimate_bytes(4, 4, 8) < 16 * 2**20
    # d=7,N=7 only fits small tiles (the DESIGN.md roofline point).
    assert vmem_estimate_bytes(7, 7, 4) > 16 * 2**20
    assert vmem_estimate_bytes(7, 7, 1) < 16 * 2**20
