"""AOT lowering: JAX/Pallas graphs -> HLO *text* artifacts + golden files.

Run once at build time (``make artifacts``); the Rust runtime then loads
``artifacts/*.hlo.txt`` via ``HloModuleProto::from_text_file`` and executes
them on the PJRT CPU client. Python never runs at serving time.

HLO **text** (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Also written:

- ``artifacts/MANIFEST.json`` — machine-readable registry of every
  artifact's kind and shapes, consumed by ``rust/src/runtime/artifact.rs``.
- ``artifacts/golden/*.json`` — deterministic input/output pairs computed
  by the jnp oracle (``ref.py``), pinning the Rust native engine to the
  Python reference in ``cargo test``.
"""

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def lower_signature(b, length, d, depth, use_pallas):
    tile = 1 if b == 1 else (8 if b % 8 == 0 else 1)
    fn = functools.partial(model.signature_fn, depth=depth, use_pallas=use_pallas, tile=tile)
    return jax.jit(fn).lower(spec(b, length, d))


def lower_signature_grad(b, length, d, depth):
    """(path, cotangent) -> grad_path, via jax.vjp through the scan."""

    def fn(path, g):
        _, vjp = jax.vjp(lambda p: ref.signature_ref(p, depth), path)
        return vjp(g)[0]

    return jax.jit(fn).lower(spec(b, length, d), spec(b, ref.sig_len(d, depth)))


def lower_logsignature(b, length, d, depth, use_pallas):
    tile = 1 if b == 1 else (8 if b % 8 == 0 else 1)
    fn = functools.partial(model.logsignature_fn, depth=depth, use_pallas=use_pallas, tile=tile)
    return jax.jit(fn).lower(spec(b, length, d))


def lower_train_step(b, length, d_in, hidden, d_out, depth):
    params = model.init_params(d_in, hidden, d_out, depth)
    fn = functools.partial(model.train_step, depth=depth, use_pallas=False)
    param_specs = tuple(jax.ShapeDtypeStruct(p.shape, p.dtype) for p in params)
    return jax.jit(lambda pr, x, y, lr: fn(model.DeepSigParams(*pr), x, y, lr)).lower(
        param_specs, spec(b, length, d_in), spec(b), spec()
    )


def write(path, text):
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def build_artifacts(out_dir: str, sweep: str) -> list:
    os.makedirs(out_dir, exist_ok=True)
    manifest = []

    def emit(name, lowered, entry):
        t0 = time.time()
        n = write(os.path.join(out_dir, name), to_hlo_text(lowered))
        entry = dict(entry)
        entry["file"] = name
        manifest.append(entry)
        print(f"  {name}: {n} chars ({time.time() - t0:.1f}s)")

    # --- Showcase artifacts: the Pallas L1 kernel inside the L2 scan. ---
    for b in (32, 1):
        cfg = dict(kind="sig", b=b, length=128, d=4, depth=4, pallas=True,
                   out_dim=ref.sig_len(4, 4))
        emit(f"sig_b{b}_L128_d4_N4.hlo.txt",
             lower_signature(b, 128, 4, 4, use_pallas=True), cfg)
    cfg = dict(kind="logsig", b=32, length=128, d=4, depth=4, pallas=True,
               out_dim=ref.witt_dimension(4, 4))
    emit("logsig_b32_L128_d4_N4.hlo.txt",
         lower_logsignature(32, 128, 4, 4, use_pallas=True), cfg)

    # --- The deep-signature training step (§6.2 / Fig 3). ---
    d_in, hidden, d_out, depth_t, b_t, L_t = 2, 16, 4, 3, 32, 64
    cfg = dict(kind="train", b=b_t, length=L_t, d=d_in, hidden=hidden,
               d_out=d_out, depth=depth_t, out_dim=0)
    emit("train_b32_L64.hlo.txt",
         lower_train_step(b_t, L_t, d_in, hidden, d_out, depth_t), cfg)

    # --- Sweep artifacts: the XLA column of the paper's tables. ---
    if sweep == "none":
        sweep_cfgs = []
    else:
        chans = range(2, 8) if sweep == "paper" else range(2, 5)
        depths = range(2, 10) if sweep == "paper" else range(2, 7)
        sweep_cfgs = [(d, 7) for d in chans] + [(4, n) for n in depths]
    for b in (32, 1):
        for d, n in sorted(set(sweep_cfgs)):
            cfg = dict(kind="sig", b=b, length=128, d=d, depth=n, pallas=False,
                       out_dim=ref.sig_len(d, n))
            emit(f"sig_b{b}_L128_d{d}_N{n}.hlo.txt",
                 lower_signature(b, 128, d, n, use_pallas=False), cfg)
            cfg = dict(kind="siggrad", b=b, length=128, d=d, depth=n, pallas=False,
                       out_dim=128 * d)
            emit(f"siggrad_b{b}_L128_d{d}_N{n}.hlo.txt",
                 lower_signature_grad(b, 128, d, n), cfg)
            cfg = dict(kind="logsig", b=b, length=128, d=d, depth=n, pallas=False,
                       out_dim=ref.witt_dimension(d, n))
            emit(f"logsig_b{b}_L128_d{d}_N{n}.hlo.txt",
                 lower_logsignature(b, 128, d, n, use_pallas=False), cfg)
    return manifest


def build_golden(out_dir: str):
    """Deterministic oracle input/output pairs for the Rust engine tests."""
    gdir = os.path.join(out_dir, "golden")
    os.makedirs(gdir, exist_ok=True)
    cases = [(2, 3, 8), (3, 4, 6), (4, 4, 10), (1, 5, 7), (5, 2, 9), (2, 6, 12)]
    for d, depth, length in cases:
        rng = np.random.default_rng(1000 * d + 10 * depth + length)
        path = (rng.normal(size=(length, d)).astype(np.float32) * 0.3).cumsum(axis=0)
        jpath = jnp.asarray(path)[None]  # (1, L, d)
        sig = ref.signature_ref(jpath, depth)[0]
        logsig = ref.logsignature_words_ref(jpath, depth)[0]
        # Gradient of sum(sig) wrt the path.
        grad = jax.grad(lambda p: jnp.sum(ref.signature_ref(p, depth)))(jpath)[0]
        stream = ref.signature_stream_ref(jpath, depth)[0]
        blob = {
            "d": d,
            "depth": depth,
            "length": length,
            "path": [float(v) for v in np.asarray(path).ravel()],
            "sig": [float(v) for v in np.asarray(sig).ravel()],
            "logsig_words": [float(v) for v in np.asarray(logsig).ravel()],
            "grad_sum_sig": [float(v) for v in np.asarray(grad).ravel()],
            "stream_last2": [float(v) for v in np.asarray(stream[-2:]).ravel()],
        }
        name = f"golden_d{d}_N{depth}_L{length}.json"
        with open(os.path.join(gdir, name), "w") as f:
            json.dump(blob, f)
        print(f"  golden/{name}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--sweep", default="small", choices=["none", "small", "paper"])
    args = ap.parse_args()
    t0 = time.time()
    print(f"lowering artifacts to {args.out} (sweep={args.sweep})")
    manifest = build_artifacts(args.out, args.sweep)
    build_golden(args.out)
    with open(os.path.join(args.out, "MANIFEST.json"), "w") as f:
        json.dump({"artifacts": manifest, "sweep": args.sweep}, f, indent=1)
    print(f"done: {len(manifest)} artifacts in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
