"""L1: the fused multiply-exponentiate as a Pallas kernel.

One kernel invocation advances the signature state of a *tile of the batch*
by one path increment: ``state <- state ⊠ exp(z)`` via the Horner scheme of
§4.1 (eq. 5) — the same operation as ``rust/src/ta/fused.rs`` and
``ref.fused_step_ref``.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the flat signature state
(``sig_len = Σ d^k`` floats per batch element) is the VMEM-resident
carry; the grid runs over batch tiles so each element's state is loaded
from HBM once per step and stored once. The Horner inner products are
rank-expansions (vector ⊗ vector → matrix, …) executed on the VPU; there
is no matmul, so the MXU is idle and the kernel is bandwidth-bound —
the roofline argument lives in DESIGN.md.

``interpret=True`` is mandatory here: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute. Interpret mode lowers the
kernel to plain HLO ops, which is exactly what the AOT artifacts need.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _fused_step_kernel(state_ref, z_ref, out_ref, *, d: int, depth: int):
    """Pallas kernel body: rows of a batch tile, flat signature layout."""
    offs = ref.level_offsets(d, depth)
    state = state_ref[...]          # (tile, sig_len)
    z = z_ref[...]                  # (tile, d)
    lv = [state[:, offs[k - 1]: offs[k]] for k in range(1, depth + 1)]
    out = [lv[0] + z]
    for k in range(2, depth + 1):
        b = z * (1.0 / k) + lv[0]
        for i in range(2, k + 1):
            m = k - i + 1
            zm = z * (1.0 / m)
            b = (b[:, :, None] * zm[:, None, :]).reshape(b.shape[0], -1) + lv[i - 1]
        out.append(b)
    out_ref[...] = jnp.concatenate(out, axis=-1)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def fused_step(state, z, d: int, depth: int, tile: int = 8):
    """Batched fused multiply-exponentiate via pallas_call.

    state: (batch, sig_len) f32, z: (batch, d) f32 -> (batch, sig_len).
    ``tile`` is the batch-tile (grid) block size; batch must divide by it
    (callers pad — the coordinator's dynamic batcher always supplies full
    tiles).

    Differentiable via a handwritten custom_vjp (pallas_call itself does not
    support reverse-mode autodiff; the paper's backward is handwritten too,
    §5.3) whose backward is the VJP of the jnp oracle.
    """
    batch, L = state.shape
    assert L == ref.sig_len(d, depth), (L, d, depth)
    assert z.shape == (batch, d)
    assert batch % tile == 0, f"batch {batch} not a multiple of tile {tile}"
    grid = (batch // tile,)
    return pl.pallas_call(
        functools.partial(_fused_step_kernel, d=d, depth=depth),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, L), lambda i: (i, 0)),
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile, L), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, L), state.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(state, z)


def _fused_step_fwd(state, z, d, depth, tile):
    return fused_step(state, z, d, depth, tile), (state, z)


def _fused_step_bwd(d, depth, tile, res, g):
    state, z = res
    _, vjp = jax.vjp(lambda s, zz: ref.fused_step_ref(s, zz, d, depth), state, z)
    return vjp(g)


fused_step.defvjp(_fused_step_fwd, _fused_step_bwd)


def signature_pallas(path, depth: int, tile: int = 8):
    """Sig^N of a batch of paths using the Pallas fused-step kernel.

    path: (batch, L, d) -> (batch, sig_len). The scan carries the signature
    state through one pallas_call per increment; in the lowered HLO the
    kernel body appears once inside the scan's while-loop body.
    """
    batch, length, d = path.shape
    incr = path[:, 1:, :] - path[:, :-1, :]
    state = ref.tensor_exp(incr[:, 0, :], depth)

    def step(s, z):
        return fused_step(s, z, d, depth, tile), None

    zs = jnp.moveaxis(incr[:, 1:, :], 1, 0)
    state, _ = jax.lax.scan(step, state, zs)
    return state


def vmem_estimate_bytes(d: int, depth: int, tile: int) -> int:
    """Estimated VMEM footprint of one kernel instance (state tile + z tile
    + output tile + the largest Horner intermediate), for DESIGN.md's
    roofline table."""
    L = ref.sig_len(d, depth)
    horner_max = d ** max(depth - 1, 1)
    floats = tile * (2 * L + d + horner_max)
    return 4 * floats
