"""Pure-jnp reference oracle for the signature algebra (L1 correctness).

Everything here is deliberately straightforward jax.numpy — no Pallas, no
cleverness — so it can serve as the ground truth that the Pallas kernel
(`fused_step.py`), the L2 model (`model.py`), and (via golden files) the
Rust native engine are all checked against.

Conventions match the Rust side (`rust/src/ta/`): a depth-N signature over
d channels is a flat vector of length `sig_len(d, N) = d + d^2 + ... + d^N`,
levels concatenated, the scalar (k=0) term implicit. Batched variants carry
leading batch axes.
"""

import math
from functools import lru_cache

import jax
import jax.numpy as jnp


@lru_cache(maxsize=None)
def level_offsets(d: int, depth: int):
    """Offsets of each level in the flat signature vector.

    Returns a tuple of length depth+1; level k (1-based) occupies
    [offsets[k-1], offsets[k]).
    """
    offs = [0]
    for k in range(1, depth + 1):
        offs.append(offs[-1] + d**k)
    return tuple(offs)


def sig_len(d: int, depth: int) -> int:
    """d + d^2 + ... + d^depth (the paper's "signature channels")."""
    return level_offsets(d, depth)[-1]


def levels_of(sig, d: int, depth: int):
    """Split a flat signature (leading batch axes allowed) into levels."""
    offs = level_offsets(d, depth)
    return [sig[..., offs[k - 1]: offs[k]] for k in range(1, depth + 1)]


def flatten_levels(levels):
    return jnp.concatenate(levels, axis=-1)


def tensor_exp(z, depth: int):
    """exp(z) = (z, z⊗z/2!, ..., z^⊗depth/depth!) flattened. z: (..., d)."""
    levels = [z]
    for k in range(2, depth + 1):
        nxt = levels[-1][..., :, None] * z[..., None, :] / k
        levels.append(nxt.reshape(*z.shape[:-1], -1))
    return flatten_levels(levels)


def sig_mul(a, b, d: int, depth: int):
    """Truncated tensor product a ⊠ b with implicit unit scalar terms."""
    la = levels_of(a, d, depth)
    lb = levels_of(b, d, depth)
    out = []
    for k in range(1, depth + 1):
        acc = la[k - 1] + lb[k - 1]
        for i in range(1, k):
            j = k - i
            prod = la[i - 1][..., :, None] * lb[j - 1][..., None, :]
            acc = acc + prod.reshape(acc.shape)
        out.append(acc)
    return flatten_levels(out)


def fused_step_ref(state, z, d: int, depth: int):
    """state ⊠ exp(z) via the paper's Horner scheme (§4.1, eq. 5).

    state: (..., sig_len), z: (..., d). The reference for the Pallas kernel.
    """
    lv = levels_of(state, d, depth)
    out = [lv[0] + z]
    for k in range(2, depth + 1):
        b = z / k + lv[0]
        for i in range(2, k + 1):
            m = k - i + 1
            b = (b[..., :, None] * (z / m)[..., None, :]).reshape(
                *z.shape[:-1], -1
            ) + lv[i - 1]
        out.append(b)
    return flatten_levels(out)


def signature_ref(path, depth: int):
    """Sig^N of a path, shape (..., L, d) -> (..., sig_len).

    Plain scan of the fused step — the oracle for both the Pallas-kernel
    model and (through golden files) the Rust engine.
    """
    d = path.shape[-1]
    incr = path[..., 1:, :] - path[..., :-1, :]
    state = tensor_exp(incr[..., 0, :], depth)

    def step(s, z):
        return fused_step_ref(s, z, d, depth), None

    # Move the stream axis to the front for scan.
    zs = jnp.moveaxis(incr[..., 1:, :], -2, 0)
    state, _ = jax.lax.scan(step, state, zs)
    return state


def signature_stream_ref(path, depth: int):
    """All prefix signatures, (..., L, d) -> (..., L-1, sig_len)."""
    d = path.shape[-1]
    incr = path[..., 1:, :] - path[..., :-1, :]
    state = tensor_exp(incr[..., 0, :], depth)

    def step(s, z):
        nxt = fused_step_ref(s, z, d, depth)
        return nxt, nxt

    zs = jnp.moveaxis(incr[..., 1:, :], -2, 0)
    _, tail = jax.lax.scan(step, state, zs)
    tail = jnp.moveaxis(tail, 0, -2)
    return jnp.concatenate([state[..., None, :], tail], axis=-2)


def sig_mul_nounit(a, b, d: int, depth: int):
    """⊠ treating both inputs as having zero scalar term."""
    la = levels_of(a, d, depth)
    lb = levels_of(b, d, depth)
    out = []
    for k in range(1, depth + 1):
        acc = jnp.zeros_like(la[k - 1])
        for i in range(1, k):
            j = k - i
            prod = la[i - 1][..., :, None] * lb[j - 1][..., None, :]
            acc = acc + prod.reshape(acc.shape)
        out.append(acc)
    return flatten_levels(out)


def tensor_log(x, d: int, depth: int):
    """log(1 + x) for the non-unit part x of a group-like element.

    Horner over (scalar, tensor) pairs, mirroring rust/src/ta/log.rs.
    """
    if depth == 1:
        return x
    s = 1.0 / depth
    t = jnp.zeros_like(x)
    for m in range(depth - 1, 0, -1):
        xt = sig_mul_nounit(x, t, d, depth)
        t = -(s * x + xt)
        s = 1.0 / m
    return x + sig_mul_nounit(x, t, d, depth)


# ---------------------------------------------------------------------------
# Lyndon machinery (mirrors rust/src/words/) for the Words-basis
# logsignature and its golden files.
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def lyndon_words(d: int, max_len: int):
    """All Lyndon words over d letters of length <= max_len (Duval)."""
    if d == 1:
        return ((0,),)
    out = []
    w = [0]
    while w:
        out.append(tuple(w))
        base = list(w)
        w = [base[i % len(base)] for i in range(max_len)]
        while w and w[-1] == d - 1:
            w.pop()
        if w:
            w[-1] += 1
    return tuple(out)


def word_index(word, d: int) -> int:
    idx = 0
    for c in word:
        idx = idx * d + c
    return idx


@lru_cache(maxsize=None)
def lyndon_flat_indices(d: int, depth: int):
    """Flat indices into the signature vector of every Lyndon word,
    ordered by (level, lex) to match the Rust LogSigPlan."""
    offs = level_offsets(d, depth)
    entries = []
    for w in lyndon_words(d, depth):
        k = len(w)
        entries.append((k, word_index(w, d)))
    entries.sort()
    return tuple(offs[k - 1] + idx for k, idx in entries)


def witt_dimension(d: int, depth: int) -> int:
    return len(lyndon_flat_indices(d, depth))


def logsignature_words_ref(path, depth: int):
    """LogSig in the paper's Words basis: gather of log(Sig) at Lyndon
    positions (App. A.2.3)."""
    d = path.shape[-1]
    sig = signature_ref(path, depth)
    logt = tensor_log(sig, d, depth)
    idx = jnp.asarray(lyndon_flat_indices(d, depth))
    return logt[..., idx]


def witt_check(d: int, depth: int) -> int:
    """Witt's formula, used to cross-check lyndon_flat_indices."""
    def mobius(n):
        result, m, p = 1, n, 2
        while p * p <= m:
            if m % p == 0:
                m //= p
                if m % p == 0:
                    return 0
                result = -result
            p += 1
        if m > 1:
            result = -result
        return result

    total = 0
    for k in range(1, depth + 1):
        s = sum(mobius(k // i) * d**i for i in range(1, k + 1) if k % i == 0)
        total += s // k
    return total


def count_fused_muls(d: int, depth: int) -> int:
    """F(d, N) of App. A.1.2 (eq. 11) — mirrored from rust/src/ta/opcount.rs."""
    total = d * (depth - 1)
    for k in range(1, depth + 1):
        for i in range(2, k + 1):
            total += d**i
    return total


def count_conventional_muls(d: int, depth: int) -> int:
    """C(d, N) of App. A.1.1 (eq. 9)."""
    total = 0
    for k in range(2, depth + 1):
        total += d + math.comb(d + k - 1, k)
    for k in range(1, depth + 1):
        total += (k - 1) * d**k
    return total
