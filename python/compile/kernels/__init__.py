"""L1 kernels: the Pallas fused multiply-exponentiate and its jnp oracle."""
