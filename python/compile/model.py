"""L2: JAX compute graphs lowered to the AOT artifacts.

Three graphs, all calling the L1 kernel (or its jnp oracle):

- ``signature_fn`` — batched signature forward, the accelerator-path
  analogue of Signatory's GPU ``signature()``.
- ``logsignature_fn`` — batched Words-basis logsignature (§4.3).
- ``train_step`` — one optimisation step of the paper's deep signature
  model (§6.2): a pointwise feedforward network swept over the input
  sequence, the signature transform, then a learnt linear map to a binary
  logit; BCE loss, SGD update. Backpropagation *through the signature* is
  taken by jax.grad through the scan of fused steps.

Python never runs at serving time: each graph is lowered once by aot.py to
HLO text and executed from the Rust runtime.
"""

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.fused_step import signature_pallas


def signature_fn(path, depth: int, use_pallas: bool = True, tile: int = 8):
    """Batched Sig^N, (b, L, d) -> (b, sig_len)."""
    if use_pallas:
        return signature_pallas(path, depth, tile=tile)
    return ref.signature_ref(path, depth)


def logsignature_fn(path, depth: int, use_pallas: bool = True, tile: int = 8):
    """Batched Words-basis LogSig^N, (b, L, d) -> (b, witt_dim)."""
    d = path.shape[-1]
    sig = signature_fn(path, depth, use_pallas=use_pallas, tile=tile)
    logt = ref.tensor_log(sig, d, depth)
    idx = jnp.asarray(ref.lyndon_flat_indices(d, depth))
    return logt[..., idx]


class DeepSigParams(NamedTuple):
    """Parameters of the deep signature model (a flat tuple so the Rust
    runtime can pass them positionally to the AOT train step)."""

    w1: jax.Array  # (d_in, hidden)
    b1: jax.Array  # (hidden,)
    w2: jax.Array  # (hidden, d_out)
    b2: jax.Array  # (d_out,)
    w_out: jax.Array  # (sig_len(d_out, depth),)
    b_out: jax.Array  # ()


def init_params(d_in: int, hidden: int, d_out: int, depth: int, seed: int = 0) -> DeepSigParams:
    """He-style init, deterministic in `seed`."""
    import numpy as np

    rng = np.random.default_rng(seed)
    sl = ref.sig_len(d_out, depth)

    def norm(shape, scale):
        return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale)

    return DeepSigParams(
        w1=norm((d_in, hidden), (2.0 / d_in) ** 0.5),
        b1=jnp.zeros((hidden,), jnp.float32),
        w2=norm((hidden, d_out), (2.0 / hidden) ** 0.5),
        b2=jnp.zeros((d_out,), jnp.float32),
        w_out=norm((sl,), (1.0 / sl) ** 0.5),
        b_out=jnp.zeros((), jnp.float32),
    )


def deep_sig_logits(params: DeepSigParams, x, depth: int, use_pallas: bool, tile: int):
    """x: (b, L, d_in) -> logits (b,).

    The 'small feedforward network swept over the input sequence' of §6.2,
    then the signature transform, then a learnt linear map.
    """
    h = jnp.tanh(x @ params.w1 + params.b1)
    hidden_path = h @ params.w2 + params.b2  # (b, L, d_out)
    sig = signature_fn(hidden_path, depth, use_pallas=use_pallas, tile=tile)
    return sig @ params.w_out + params.b_out


def bce_loss(params: DeepSigParams, x, y, depth: int, use_pallas: bool, tile: int):
    """Binary cross-entropy with logits; y in {0, 1}, shape (b,)."""
    logits = deep_sig_logits(params, x, depth, use_pallas, tile)
    # log-sum-exp stable BCE.
    return jnp.mean(jnp.maximum(logits, 0.0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def train_step(params: DeepSigParams, x, y, lr, depth: int, use_pallas: bool = False, tile: int = 8):
    """One SGD step. Returns (new_params..., loss) as a flat tuple so the
    lowered artifact has a simple positional calling convention."""
    loss, grads = jax.value_and_grad(bce_loss)(params, x, y, depth, use_pallas, tile)
    new = DeepSigParams(*(p - lr * g for p, g in zip(params, grads)))
    return tuple(new) + (loss,)


def predict_accuracy(params: DeepSigParams, x, y, depth: int, use_pallas: bool = False, tile: int = 8):
    logits = deep_sig_logits(params, x, depth, use_pallas, tile)
    return jnp.mean(((logits > 0).astype(jnp.float32) == y).astype(jnp.float32))
